//! Parity of the parallel topology pipeline against the serial reference
//! path: the Sort half ([`Pyramid::build_threaded`]) must produce
//! bit-identical pyramids (`starts`, `rects`, particle permutation,
//! `SortStats`) and the Connect half ([`Connectivity::build_threaded`])
//! byte-identical CSR lists (`offsets`, `data`, `checks`) — across
//! distributions, levels, θ values, partition engines, and thread counts
//! including 1, 2, odd, and more threads than boxes.

use fmm2d::connectivity::Connectivity;
use fmm2d::topology::{self, TopologyOptions};
use fmm2d::tree::{PartitionEngine, Pyramid};
use fmm2d::util::rng::Pcg64;
use fmm2d::workload::Distribution;

/// 1, 2, an odd count, and far more threads than level-1 (and often leaf)
/// boxes — the degenerate fan-outs the sharding must survive.
const THREAD_COUNTS: [usize; 5] = [1, 2, 3, 7, 4096];

fn assert_pyramids_identical(a: &Pyramid, b: &Pyramid, what: &str) {
    assert_eq!(a.levels, b.levels, "{what}: levels");
    assert_eq!(a.starts, b.starts, "{what}: starts");
    for l in 0..=a.levels {
        for (i, (ra, rb)) in a.rects[l].iter().zip(&b.rects[l]).enumerate() {
            assert_eq!(ra.x0, rb.x0, "{what}: rect l={l} b={i} x0");
            assert_eq!(ra.x1, rb.x1, "{what}: rect l={l} b={i} x1");
            assert_eq!(ra.y0, rb.y0, "{what}: rect l={l} b={i} y0");
            assert_eq!(ra.y1, rb.y1, "{what}: rect l={l} b={i} y1");
        }
    }
    for (i, (pa, pb)) in a.particles.iter().zip(&b.particles).enumerate() {
        assert_eq!(pa.orig, pb.orig, "{what}: particle {i} permutation");
        assert_eq!(pa.pos, pb.pos, "{what}: particle {i} pos");
        assert_eq!(pa.gamma, pb.gamma, "{what}: particle {i} gamma");
    }
    assert_eq!(a.sort_stats.splits, b.sort_stats.splits, "{what}: splits");
    assert_eq!(
        a.sort_stats.elements_visited, b.sort_stats.elements_visited,
        "{what}: elements_visited"
    );
    assert_eq!(a.sort_stats.passes, b.sort_stats.passes, "{what}: passes");
    assert_eq!(
        a.sort_stats.scattered, b.sort_stats.scattered,
        "{what}: scattered"
    );
}

fn assert_connectivity_identical(a: &Connectivity, b: &Connectivity, what: &str) {
    assert_eq!(a.checks, b.checks, "{what}: checks");
    assert_eq!(a.weak.len(), b.weak.len(), "{what}: weak levels");
    for (l, (wa, wb)) in a.weak.iter().zip(&b.weak).enumerate() {
        assert_eq!(wa.offsets, wb.offsets, "{what}: weak offsets l={l}");
        assert_eq!(wa.data, wb.data, "{what}: weak data l={l}");
    }
    for (name, la, lb) in [
        ("near", &a.near, &b.near),
        ("p2l", &a.p2l, &b.p2l),
        ("m2p", &a.m2p, &b.m2p),
    ] {
        assert_eq!(la.offsets, lb.offsets, "{what}: {name} offsets");
        assert_eq!(la.data, lb.data, "{what}: {name} data");
    }
}

#[test]
fn sort_and_connect_parity_across_the_grid() {
    let dists = [
        Distribution::Uniform,
        Distribution::Normal { sigma: 0.08 },
        Distribution::Layer { sigma: 0.05 },
    ];
    for (di, dist) in dists.iter().enumerate() {
        for levels in [1usize, 2, 3] {
            let mut r = Pcg64::seed_from_u64(400 + di as u64);
            let (pts, gs) = dist.generate(2500, &mut r);
            for engine in [PartitionEngine::Cpu, PartitionEngine::GpuModel] {
                let serial = Pyramid::build_with(&pts, &gs, levels, engine).unwrap();
                for nt in THREAD_COUNTS {
                    let what =
                        format!("{} L={levels} {engine:?} t={nt}", dist.name());
                    let par =
                        Pyramid::build_threaded(&pts, &gs, levels, engine, nt).unwrap();
                    assert_pyramids_identical(&serial, &par, &what);
                }
            }
            for theta in [0.3f64, 0.5, 0.8] {
                let pyr = Pyramid::build(&pts, &gs, levels).unwrap();
                let serial = Connectivity::build(&pyr, theta);
                for nt in THREAD_COUNTS {
                    let what = format!("{} L={levels} θ={theta} t={nt}", dist.name());
                    let par = Connectivity::build_threaded(&pyr, theta, nt);
                    assert_connectivity_identical(&serial, &par, &what);
                }
            }
        }
    }
}

#[test]
fn unified_topology_layer_parity() {
    // the topology::build entry point: Serial and Parallel engines agree
    // on everything downstream consumes, at several worker counts
    let mut r = Pcg64::seed_from_u64(900);
    let (pts, gs) = Distribution::Normal { sigma: 0.1 }.generate(4000, &mut r);
    let serial = topology::build(&pts, &gs, 4, &TopologyOptions::serial(0.5)).unwrap();
    for nt in [2usize, 5, 64] {
        let par =
            topology::build(&pts, &gs, 4, &TopologyOptions::parallel(0.5, nt)).unwrap();
        assert_pyramids_identical(&serial.pyramid, &par.pyramid, &format!("topo t={nt}"));
        assert_connectivity_identical(
            &serial.connectivity,
            &par.connectivity,
            &format!("topo t={nt}"),
        );
    }
}

#[test]
fn gpu_model_stats_survive_the_parallel_build() {
    // the GPU-model partition engine's scatter counters feed the cost
    // simulator; the parallel fan-out must not change them
    let mut r = Pcg64::seed_from_u64(901);
    let (pts, gs) = Distribution::Uniform.generate(20_000, &mut r);
    let serial = Pyramid::build_with(&pts, &gs, 4, PartitionEngine::GpuModel).unwrap();
    let par =
        Pyramid::build_threaded(&pts, &gs, 4, PartitionEngine::GpuModel, 6).unwrap();
    assert!(serial.sort_stats.scattered > 0);
    assert_eq!(serial.sort_stats.scattered, par.sort_stats.scattered);
}

#[test]
fn structural_validators_pass_on_built_topologies() {
    // explicit release-mode-style coverage: validate the exact structures
    // the parity assertions above compare (debug builds additionally run
    // the validators inside every topology::build)
    let dists = [
        Distribution::Uniform,
        Distribution::Normal { sigma: 0.08 },
        Distribution::Layer { sigma: 0.05 },
    ];
    for (di, dist) in dists.iter().enumerate() {
        let mut r = Pcg64::seed_from_u64(950 + di as u64);
        let (pts, gs) = dist.generate(3000, &mut r);
        for levels in [1usize, 3] {
            let topo =
                topology::build(&pts, &gs, levels, &TopologyOptions::parallel(0.5, 4)).unwrap();
            topo.pyramid.validate().unwrap();
            topo.connectivity.validate(&topo.pyramid).unwrap();
        }
    }
}

#[test]
fn structural_validators_reject_corrupted_topologies() {
    let mut r = Pcg64::seed_from_u64(960);
    let (pts, gs) = Distribution::Uniform.generate(2000, &mut r);
    let topo = topology::build(&pts, &gs, 3, &TopologyOptions::serial(0.5)).unwrap();

    // broken exclusive scan: starts no longer begins at 0
    let mut pyr = topo.pyramid.clone();
    pyr.starts[0] = 1;
    assert!(pyr.validate().is_err(), "corrupted starts must be rejected");

    // broken permutation: a duplicated orig index
    let mut pyr = topo.pyramid.clone();
    pyr.particles[0].orig = pyr.particles[1].orig;
    assert!(pyr.validate().is_err(), "duplicate orig must be rejected");

    // broken containment: a particle teleported outside its leaf box
    let mut pyr = topo.pyramid.clone();
    pyr.particles[0].pos = fmm2d::complex::C64::new(1e9, 1e9);
    assert!(
        pyr.validate().is_err(),
        "escaped particle must be rejected"
    );

    // broken CSR: near data grows without its offsets
    let mut con = topo.connectivity.clone();
    con.near.data.push(0);
    assert!(
        con.validate(&topo.pyramid).is_err(),
        "CSR length mismatch must be rejected"
    );

    // broken symmetry: a one-directional near entry
    let mut con = topo.connectivity.clone();
    let extra = {
        // a box that is not already a near source of box 0: the farthest one
        (topo.pyramid.n_leaves() - 1) as u32
    };
    if !con.near.sources(0).contains(&extra) {
        let at = con.near.offsets[1] as usize;
        con.near.data.insert(at, extra);
        for off in con.near.offsets.iter_mut().skip(1) {
            *off += 1;
        }
        assert!(
            con.validate(&topo.pyramid).is_err(),
            "asymmetric near field must be rejected"
        );
    }
}

#[test]
fn topology_errors_are_results_not_panics() {
    let mut r = Pcg64::seed_from_u64(902);
    let (pts, gs) = Distribution::Uniform.generate(20, &mut r);
    for nt in [1usize, 4] {
        let err = Pyramid::build_threaded(&pts, &gs, 3, PartitionEngine::Cpu, nt)
            .unwrap_err()
            .to_string();
        assert!(err.contains("fewer particles"), "t={nt}: {err}");
    }
    let err = topology::build(&pts, &gs, 0, &TopologyOptions::default())
        .unwrap_err()
        .to_string();
    assert!(err.contains("refinement level"), "{err}");
}
