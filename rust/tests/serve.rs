//! End-to-end tests of the serve transport and the protocol error paths
//! on the default build. Every test drives a full [`serve_lines`] session
//! (reader + engine thread + reply sink) through an in-memory transport
//! and audits the reply stream for the exactly-once invariant. The
//! injected-panic scenarios need `--features failpoints` and live in
//! `serve_chaos.rs`.

use std::io::Cursor;
use std::sync::Arc;

use fmm2d::dispatch::{Dispatcher, Engine};
use fmm2d::fmm::{self, CpuEngine, FmmOptions};
use fmm2d::serve::{serve_lines, ServeOptions, ServeOutcome};
use fmm2d::util::json::Json;
use fmm2d::workload::Distribution;

fn opts() -> ServeOptions {
    ServeOptions {
        fmm: FmmOptions {
            threads: Some(2),
            ..FmmOptions::default()
        },
        ..ServeOptions::default()
    }
}

/// Run one full session over an in-memory transport and parse the reply
/// stream.
fn run_session(input: &str, opts: ServeOptions) -> (Vec<Json>, ServeOutcome) {
    let mut out: Vec<u8> = Vec::new();
    let outcome = serve_lines(Cursor::new(input.to_string()), &mut out, opts).unwrap();
    let replies = String::from_utf8(out)
        .unwrap()
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap())
        .collect();
    (replies, outcome)
}

fn status_of(r: &Json) -> &str {
    r.get("status").and_then(Json::as_str).unwrap()
}

fn id_of(r: &Json) -> Option<u64> {
    match r.get("id") {
        Some(Json::Null) | None => None,
        Some(v) => v.as_f64().map(|x| x as u64),
    }
}

fn potentials_of(r: &Json) -> Vec<(f64, f64)> {
    match r.get("potentials") {
        Some(Json::Arr(items)) => items
            .iter()
            .map(|it| match it {
                Json::Arr(p) => (p[0].as_f64().unwrap(), p[1].as_f64().unwrap()),
                other => panic!("bad potential entry {other:?}"),
            })
            .collect(),
        other => panic!("reply carries no potentials: {other:?}"),
    }
}

/// The daemon's potentials must be *bit-identical* to an offline
/// `fmm::evaluate` of the same deterministic workload at the engine ×
/// worker count the reply advertises — the same contract `fmm2d loadgen`
/// gates on via digests, checked here value by value.
#[test]
fn replies_are_bitwise_identical_to_offline_evaluation() {
    let input = "{\"id\":1,\"n\":500,\"seed\":7}\n{\"id\":2,\"n\":900,\"seed\":8}\n";
    let (replies, outcome) = run_session(input, opts());
    assert_eq!(replies.len(), 2, "{replies:?}");
    assert!(!outcome.shutdown);
    assert_eq!(outcome.stats.ok, 2);
    for r in &replies {
        assert_eq!(status_of(r), "ok");
        let id = id_of(r).unwrap();
        let workers = r.get("workers").and_then(Json::as_usize).unwrap();
        let (n, seed) = if id == 1 { (500, 7) } else { (900, 8) };
        let got = potentials_of(r);
        assert_eq!(got.len(), n);
        let (pts, gs) = fmm2d::harness::workload_for(Distribution::Uniform, n, seed);
        let offline = fmm::evaluate(
            &pts,
            &gs,
            &FmmOptions {
                threads: Some(workers),
                cpu_engine: CpuEngine::Barrier,
                ..FmmOptions::default()
            },
        )
        .unwrap();
        for (i, (re, im)) in got.iter().enumerate() {
            assert_eq!(re.to_bits(), offline.potentials[i].re.to_bits(), "id {id} re[{i}]");
            assert_eq!(im.to_bits(), offline.potentials[i].im.to_bits(), "id {id} im[{i}]");
        }
    }
}

/// Hostile and malformed lines each get exactly one structured `error`
/// reply — with the id salvaged whenever the line could still carry one —
/// and the daemon keeps serving afterwards.
#[test]
fn malformed_lines_get_error_replies_and_service_continues() {
    let input = concat!(
        "this is not json\n",
        "{\"id\":3,\"n\":1000\n",               // truncated — id unsalvageable
        "{\"id\":4,\"bogus\":1,\"n\":500}\n",   // unknown field
        "{\"id\":5,\"n\":\"x\"}\n",             // wrong type
        "{\"id\":6,\"n\":100000000}\n",         // oversized n
        "{\"id\":7,\"n\":500,\"theta\":1e999}\n", // non-finite smuggled via overflow
        "{\"id\":8,\"n\":50,\"p\":0}\n",        // out-of-range p
        "\n",                                   // blank lines are skipped
        "{\"id\":9,\"n\":500,\"digest\":true}\n", // still alive?
    );
    let (replies, outcome) = run_session(input, opts());
    assert_eq!(replies.len(), 8, "{replies:?}");
    let errors: Vec<Option<u64>> = replies[..7].iter().map(id_of).collect();
    for r in &replies[..7] {
        assert_eq!(status_of(r), "error", "{r:?}");
    }
    // the first two lines cannot carry an id; the rest salvage theirs
    assert_eq!(
        errors,
        [None, None, Some(4), Some(5), Some(6), Some(7), Some(8)]
    );
    assert_eq!(status_of(&replies[7]), "ok");
    assert_eq!(id_of(&replies[7]), Some(9));
    assert_eq!(outcome.stats.rejected, 7);
    assert_eq!(outcome.stats.accepted, 1);
}

/// An inline request with non-finite coordinates is rejected at the
/// boundary (satellite: input validation), not discovered as a poisoned
/// tree later.
#[test]
fn non_finite_inline_points_are_rejected() {
    let input = "{\"id\":1,\"points\":[[0.1,0.2],[0.3,1e999],[0.5,0.5],[0.7,0.7]],\
                 \"gammas\":[[1,0],[1,0],[1,0],[1,0]]}\n";
    let (replies, outcome) = run_session(input, opts());
    assert_eq!(replies.len(), 1);
    assert_eq!(status_of(&replies[0]), "error");
    assert_eq!(id_of(&replies[0]), Some(1));
    assert_eq!(outcome.stats.accepted, 0);
}

#[test]
fn expired_deadline_is_answered_expired() {
    let input = "{\"id\":11,\"n\":500,\"deadline_ms\":0}\n";
    let (replies, outcome) = run_session(input, opts());
    assert_eq!(replies.len(), 1);
    assert_eq!(status_of(&replies[0]), "expired");
    assert_eq!(id_of(&replies[0]), Some(11));
    assert!(replies[0].get("waited_ms").and_then(Json::as_f64).is_some());
    assert_eq!(outcome.stats.expired, 1);
}

/// Under a tiny admission bound every request is still answered exactly
/// once: `ok` if it got in, structured `overloaded` with a backoff hint if
/// it was shed. (Whether any are shed depends on reader/engine timing; the
/// deterministic shed assertions live in the server unit tests.)
#[test]
fn overload_ledger_balances_exactly_once() {
    let mut input = String::new();
    for i in 0..10 {
        input.push_str(&format!("{{\"id\":{i},\"n\":2000,\"digest\":true}}\n"));
    }
    let (replies, outcome) = run_session(
        input.as_str(),
        ServeOptions {
            max_queue: 2,
            ..opts()
        },
    );
    assert_eq!(replies.len(), 10, "{replies:?}");
    let mut ids: Vec<u64> = replies.iter().map(|r| id_of(r).unwrap()).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..10).collect::<Vec<_>>(), "each id exactly once");
    for r in &replies {
        match status_of(r) {
            "ok" => {}
            "overloaded" => {
                assert!(r.get("retry_after_ms").and_then(Json::as_usize).unwrap() >= 10);
            }
            other => panic!("unexpected status {other}: {r:?}"),
        }
    }
    assert_eq!(outcome.stats.accepted + outcome.stats.shed, 10);
    assert_eq!(outcome.stats.answered(), outcome.stats.accepted);
}

/// `shutdown` drains the queue (everything accepted is still answered) and
/// stops reading: lines after it are never processed.
#[test]
fn shutdown_drains_and_stops_reading() {
    let input = "{\"id\":1,\"n\":500,\"digest\":true}\n\
                 {\"kind\":\"shutdown\"}\n\
                 {\"id\":2,\"n\":500,\"digest\":true}\n";
    let (replies, outcome) = run_session(input, opts());
    assert!(outcome.shutdown);
    assert_eq!(replies.len(), 1, "{replies:?}");
    assert_eq!(id_of(&replies[0]), Some(1));
    assert_eq!(status_of(&replies[0]), "ok");
    assert_eq!(outcome.stats.accepted, 1);
}

/// Oversized request lines are rejected before JSON parsing with a
/// structured reply, not a hang or an unbounded allocation downstream.
#[test]
fn oversized_lines_are_rejected() {
    let mut input = String::from("{\"pad\":\"");
    input.push_str(&"x".repeat(9 << 20)); // > MAX_LINE_BYTES
    input.push_str("\"}\n{\"id\":1,\"n\":500,\"digest\":true}\n");
    let (replies, outcome) = run_session(&input, opts());
    assert_eq!(replies.len(), 2, "{replies:?}");
    assert_eq!(status_of(&replies[0]), "error");
    assert!(replies[0]
        .get("error")
        .and_then(Json::as_str)
        .unwrap()
        .contains("exceeds"));
    assert_eq!(status_of(&replies[1]), "ok");
    assert_eq!(outcome.stats.rejected, 1);
}

/// A hostile client streaming one giant line with *no newline at all*
/// (the memory-exhaustion shape) is rejected with the same structured
/// reply: the transport discards past the cap instead of buffering.
#[test]
fn unterminated_oversized_line_is_rejected() {
    let mut input = String::from("{\"id\":1,\"n\":500,\"digest\":true}\n{\"pad\":\"");
    input.push_str(&"x".repeat(9 << 20)); // > MAX_LINE_BYTES, never terminated
    let (replies, outcome) = run_session(&input, opts());
    assert_eq!(replies.len(), 2, "{replies:?}");
    // The rejection comes from the reader thread, the `ok` from the engine
    // thread — order is not deterministic.
    let mut statuses: Vec<&str> = replies.iter().map(status_of).collect();
    statuses.sort_unstable();
    assert_eq!(statuses, ["error", "ok"]);
    let rejection = replies.iter().find(|&r| status_of(r) == "error").unwrap();
    assert!(rejection
        .get("error")
        .and_then(Json::as_str)
        .unwrap()
        .contains("exceeds"));
    assert_eq!(outcome.stats.rejected, 1);
    assert_eq!(outcome.stats.ok, 1);
}

/// Satellite: `--engine auto` without a usable calibration profile must
/// not trust uncalibrated crossovers — the server resolves it to the
/// pooled engine (and says so once on stderr).
#[test]
fn auto_engine_falls_back_to_pooled_without_calibration() {
    let uncalibrated = Dispatcher {
        fallback: true,
        ..Dispatcher::default()
    };
    let (replies, _) = run_session(
        "{\"id\":1,\"n\":500,\"digest\":true}\n",
        ServeOptions {
            engine: Engine::Auto,
            dispatcher: Some(Arc::new(uncalibrated)),
            ..opts()
        },
    );
    assert_eq!(replies.len(), 1);
    assert_eq!(status_of(&replies[0]), "ok");
    assert_eq!(
        replies[0].get("engine").and_then(Json::as_str),
        Some("pooled")
    );
}

/// A *calibrated* dispatcher keeps `auto` live: the reply advertises
/// whatever CPU rung the cost model picked (never xla in serve).
#[test]
fn auto_engine_with_calibration_serves_on_a_cpu_rung() {
    let calibrated = Dispatcher::default(); // fallback rates, but not flagged
    let (replies, _) = run_session(
        "{\"id\":1,\"n\":500,\"digest\":true}\n",
        ServeOptions {
            engine: Engine::Auto,
            dispatcher: Some(Arc::new(calibrated)),
            ..opts()
        },
    );
    assert_eq!(replies.len(), 1);
    assert_eq!(status_of(&replies[0]), "ok");
    let engine = replies[0].get("engine").and_then(Json::as_str).unwrap();
    assert!(
        ["serial", "pooled", "taskgraph"].contains(&engine),
        "unexpected engine {engine}"
    );
}
