//! Micro-kernel contract tests (DESIGN.md §10).
//!
//! The blocked tile accumulators promise a *specific* lane decomposition —
//! element `j` lands in accumulator lane `(j − j0) mod LANE`, the inner
//! body is the documented FMA sequence, and the final reduction is the
//! fixed tree `(a0 + a1) + (a2 + a3)`. These tests pin that contract
//! **bitwise** against straight-line scalar models: if the loop shape the
//! vectorizer relies on changes (a different blocking, a reassociated
//! reduction, a non-FMA body), the bits move and the gate fails. The
//! remaining tests document the tiled-vs-scalar numeric distance (ULP-level
//! reassociation, bounded at 1e-12 relative) and exercise the tiled P2P
//! through every CPU engine, thread count, and particle distribution.

use fmm2d::complex::C64;
use fmm2d::direct;
use fmm2d::expansion::Kernel;
use fmm2d::fmm::{evaluate, CpuEngine, FmmOptions};
use fmm2d::harness::workload_for;
use fmm2d::tiles::{accum_harmonic, accum_scatter_harmonic, PackedPoints, LANE};
use fmm2d::util::rng::Pcg64;
use fmm2d::workload::Distribution;

// ---- scalar models of the exact lane semantics --------------------------

#[allow(clippy::too_many_arguments)]
fn model_accum_harmonic(
    xs: &[f64],
    ys: &[f64],
    gre: &[f64],
    gim: &[f64],
    j0: usize,
    j1: usize,
    xi: f64,
    yi: f64,
) -> (f64, f64) {
    let mut ar = [0.0f64; LANE];
    let mut ai = [0.0f64; LANE];
    for (idx, j) in (j0..j1).enumerate() {
        let k = idx % LANE;
        let dx = xs[j] - xi;
        let dy = ys[j] - yi;
        let inv = 1.0 / dx.mul_add(dx, dy * dy);
        let rr = dx * inv;
        let ri = -(dy * inv);
        ar[k] = gre[j].mul_add(rr, ar[k]);
        ar[k] = (-gim[j]).mul_add(ri, ar[k]);
        ai[k] = gre[j].mul_add(ri, ai[k]);
        ai[k] = gim[j].mul_add(rr, ai[k]);
    }
    ((ar[0] + ar[1]) + (ar[2] + ar[3]), (ai[0] + ai[1]) + (ai[2] + ai[3]))
}

#[allow(clippy::too_many_arguments)]
fn model_accum_scatter(
    xs: &[f64],
    ys: &[f64],
    gre: &[f64],
    gim: &[f64],
    j0: usize,
    j1: usize,
    xi: f64,
    yi: f64,
    gri: f64,
    gii: f64,
    jbase: usize,
    phr: &mut [f64],
    phm: &mut [f64],
) -> (f64, f64) {
    let mut ar = [0.0f64; LANE];
    let mut ai = [0.0f64; LANE];
    for (idx, j) in (j0..j1).enumerate() {
        let k = idx % LANE;
        let dx = xs[j] - xi;
        let dy = ys[j] - yi;
        let inv = 1.0 / dx.mul_add(dx, dy * dy);
        let rr = dx * inv;
        let ri = -(dy * inv);
        ar[k] = gre[j].mul_add(rr, ar[k]);
        ar[k] = (-gim[j]).mul_add(ri, ar[k]);
        ai[k] = gre[j].mul_add(ri, ai[k]);
        ai[k] = gim[j].mul_add(rr, ai[k]);
        let pr = gii.mul_add(ri, phr[jbase + j]);
        phr[jbase + j] = (-gri).mul_add(rr, pr);
        let pm = (-gii).mul_add(rr, phm[jbase + j]);
        phm[jbase + j] = (-gri).mul_add(ri, pm);
    }
    ((ar[0] + ar[1]) + (ar[2] + ar[3]), (ai[0] + ai[1]) + (ai[2] + ai[3]))
}

fn random_tile(n: usize, seed: u64) -> PackedPoints {
    let mut r = Pcg64::seed_from_u64(seed);
    let pts: Vec<C64> = (0..n)
        .map(|_| C64::new(r.uniform_in(0.0, 1.0), r.uniform_in(0.0, 1.0)))
        .collect();
    let gs: Vec<C64> = (0..n)
        .map(|_| C64::new(r.uniform_in(-1.0, 1.0), r.uniform_in(-1.0, 1.0)))
        .collect();
    PackedPoints::pack(&pts, &gs)
}

#[test]
fn lane_model_pins_harmonic_gather_bitwise() {
    // sizes straddle the blocking: below one lane, exact lanes, tails
    for n in [1usize, 2, 3, 4, 5, 7, 8, 11, 64, 67] {
        let t = random_tile(n, 100 + n as u64);
        let (xi, yi) = (0.31, 0.77);
        for j0 in [0usize, 1, 3] {
            if j0 >= n {
                continue;
            }
            let got = accum_harmonic(&t.xs, &t.ys, &t.gre, &t.gim, j0, n, xi, yi);
            let want = model_accum_harmonic(&t.xs, &t.ys, &t.gre, &t.gim, j0, n, xi, yi);
            assert_eq!(got.0.to_bits(), want.0.to_bits(), "n={n} j0={j0} re");
            assert_eq!(got.1.to_bits(), want.1.to_bits(), "n={n} j0={j0} im");
        }
        // full padded width: identical bits to the true-width run (padding
        // slots are exact arithmetic no-ops by construction)
        let full = accum_harmonic(&t.xs, &t.ys, &t.gre, &t.gim, 0, t.padded(), xi, yi);
        let real = accum_harmonic(&t.xs, &t.ys, &t.gre, &t.gim, 0, n, xi, yi);
        assert_eq!(full.0.to_bits(), real.0.to_bits(), "n={n} pad re");
        assert_eq!(full.1.to_bits(), real.1.to_bits(), "n={n} pad im");
    }
}

#[test]
fn lane_model_pins_harmonic_scatter_bitwise() {
    for n in [2usize, 3, 5, 9, 16, 21] {
        let t = random_tile(n, 200 + n as u64);
        let (xi, yi, gri, gii) = (0.4, 0.6, 1.25, -0.5);
        let mut phr_a = vec![0.125f64; n];
        let mut phm_a = vec![-0.25f64; n];
        let mut phr_b = phr_a.clone();
        let mut phm_b = phm_a.clone();
        let got = accum_scatter_harmonic(
            &t.xs, &t.ys, &t.gre, &t.gim, 1, n, xi, yi, gri, gii, 0, &mut phr_a, &mut phm_a,
        );
        let want = model_accum_scatter(
            &t.xs, &t.ys, &t.gre, &t.gim, 1, n, xi, yi, gri, gii, 0, &mut phr_b, &mut phm_b,
        );
        assert_eq!(got.0.to_bits(), want.0.to_bits(), "n={n} re");
        assert_eq!(got.1.to_bits(), want.1.to_bits(), "n={n} im");
        for j in 0..n {
            assert_eq!(phr_a[j].to_bits(), phr_b[j].to_bits(), "n={n} phr[{j}]");
            assert_eq!(phm_a[j].to_bits(), phm_b[j].to_bits(), "n={n} phm[{j}]");
        }
    }
}

// ---- tiled vs scalar numeric distance ------------------------------------

#[test]
fn tiled_gather_within_1e12_of_complex_reference() {
    // the tiled kernel differs from the naive complex-arithmetic sum only
    // by FMA contraction and the lane-split reassociation — ULP-level per
    // pair, documented here as ≤ 1e-12 relative on the full sum
    let n = 500;
    let mut r = Pcg64::seed_from_u64(3);
    let pts: Vec<C64> = (0..n)
        .map(|_| C64::new(r.uniform_in(0.0, 1.0), r.uniform_in(0.0, 1.0)))
        .collect();
    let gs: Vec<C64> = (0..n)
        .map(|_| C64::new(r.uniform_in(-1.0, 1.0), r.uniform_in(-1.0, 1.0)))
        .collect();
    let t = PackedPoints::pack(&pts, &gs);
    let zt = C64::new(1.5, -0.25);
    let (ar, ai) = accum_harmonic(&t.xs, &t.ys, &t.gre, &t.gim, 0, t.padded(), zt.re, zt.im);
    let mut want = C64::new(0.0, 0.0);
    for (p, g) in pts.iter().zip(&gs) {
        want += *g * (*p - zt).recip();
    }
    assert!((ar - want.re).abs() <= 1e-12 * want.re.abs().max(1.0), "{ar} vs {}", want.re);
    assert!((ai - want.im).abs() <= 1e-12 * want.im.abs().max(1.0), "{ai} vs {}", want.im);
}

#[test]
fn tiled_direct_baselines_match_scalar_reference() {
    for dist in [
        Distribution::Uniform,
        Distribution::Normal { sigma: 0.1 },
        Distribution::Layer { sigma: 0.1 },
    ] {
        let (pts, gs) = workload_for(dist, 600, 5);
        let mut scalar = vec![C64::new(0.0, 0.0); pts.len()];
        for i in 0..pts.len() {
            for j in 0..pts.len() {
                if j != i {
                    scalar[i] += gs[j] * (pts[j] - pts[i]).recip();
                }
            }
        }
        for (name, tiled) in [
            ("plain", direct::eval_plain(Kernel::Harmonic, &pts, &gs)),
            ("symmetric", direct::eval_symmetric(Kernel::Harmonic, &pts, &gs)),
        ] {
            for (i, (a, b)) in tiled.iter().zip(&scalar).enumerate() {
                assert!(
                    (*a - *b).abs() <= 1e-12 * b.abs().max(1.0),
                    "{name} {} i={i}: {a:?} vs {b:?}",
                    dist.name()
                );
            }
        }
    }
}

// ---- tiled P2P through every engine / thread count / distribution --------

#[test]
fn tiled_p2p_parity_across_engines_and_distributions() {
    for dist in [
        Distribution::Uniform,
        Distribution::Normal { sigma: 0.1 },
        Distribution::Layer { sigma: 0.1 },
    ] {
        let (pts, gs) = workload_for(dist, 4_000, 9);
        let serial = evaluate(
            &pts,
            &gs,
            &FmmOptions {
                threads: Some(1),
                ..FmmOptions::default()
            },
        )
        .unwrap();
        for threads in [2usize, 3] {
            for engine in [CpuEngine::Barrier, CpuEngine::TaskGraph] {
                let out = evaluate(
                    &pts,
                    &gs,
                    &FmmOptions {
                        threads: Some(threads),
                        cpu_engine: engine,
                        ..FmmOptions::default()
                    },
                )
                .unwrap();
                for (a, b) in serial.potentials.iter().zip(&out.potentials) {
                    assert!(
                        (*a - *b).abs() <= 1e-12 * a.abs().max(1.0),
                        "{} t={threads} {engine:?}: {a:?} vs {b:?}",
                        dist.name()
                    );
                }
            }
        }
    }
}

#[test]
fn tiled_directed_p2p_parity_across_thread_counts() {
    // the directed (GPU-layout) formulation shares the gather kernel
    let (pts, gs) = workload_for(Distribution::Normal { sigma: 0.1 }, 3_000, 11);
    let base = FmmOptions {
        symmetric_p2p: false,
        ..FmmOptions::default()
    };
    let serial = evaluate(
        &pts,
        &gs,
        &FmmOptions {
            threads: Some(1),
            ..base.clone()
        },
    )
    .unwrap();
    for threads in [2usize, 4] {
        let out = evaluate(
            &pts,
            &gs,
            &FmmOptions {
                threads: Some(threads),
                ..base.clone()
            },
        )
        .unwrap();
        for (a, b) in serial.potentials.iter().zip(&out.potentials) {
            assert!(
                (*a - *b).abs() <= 1e-12 * a.abs().max(1.0),
                "t={threads}: {a:?} vs {b:?}"
            );
        }
    }
}
