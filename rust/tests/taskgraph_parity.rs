//! Schedule-fuzz parity suite of the task-graph pipelined engine
//! (`fmm::taskgraph`, DESIGN.md §9).
//!
//! The engine's claim is *schedule independence*: because every reduction
//! order is pinned by the graph's dependency edges (or kept intra-task),
//! any dependency-respecting schedule must produce **bitwise-identical**
//! potentials — equal to the pooled barrier engine at the same thread
//! count, whose shard boundaries and per-shard kernels it shares. This
//! suite attacks that claim with randomized wakeup/claim jitter
//! ([`Jitter`]: every worker busy-waits a seeded pseudorandom interval
//! before each claim attempt), across worker counts of 1, 2, an odd
//! count, and more workers than the machine has cores, on uniform and
//! clustered particle distributions, through both P2P formulations.
//!
//! Equality is exact (`==` on f64 bit patterns via `assert_eq!`), not a
//! tolerance: the pooled engine already promises bitwise parity with the
//! serial driver, and the task-graph engine extends that promise. The
//! serial cross-check at the bottom keeps the whole chain anchored to the
//! reference driver within 1e-12.

use fmm2d::complex::C64;
use fmm2d::config::FmmConfig;
use fmm2d::connectivity::Connectivity;
use fmm2d::fmm::parallel::evaluate_on_tree_pool;
use fmm2d::fmm::taskgraph::evaluate_on_tree_taskgraph_seeded;
use fmm2d::fmm::{self, FmmOptions, WorkCounts};
use fmm2d::tree::Pyramid;
use fmm2d::util::pool::WorkerPool;
use fmm2d::util::rng::Pcg64;
use fmm2d::util::sched::Jitter;
use fmm2d::util::threadpool::available_threads;
use fmm2d::workload;

/// One prebuilt problem the whole suite reuses per distribution.
struct Case {
    pyr: Pyramid,
    con: Connectivity,
    name: &'static str,
}

fn cases() -> Vec<Case> {
    let mut r = Pcg64::seed_from_u64(97);
    let (u_pts, u_gs) = workload::uniform_square(3_000, &mut r);
    let (c_pts, c_gs) = workload::normal_cloud(3_000, 0.08, &mut r);
    [("uniform", u_pts, u_gs), ("clustered", c_pts, c_gs)]
        .into_iter()
        .map(|(name, pts, gs)| {
            let pyr = Pyramid::build(&pts, &gs, 3).expect("3 levels fit 3000 points");
            let con = Connectivity::build(&pyr, 0.5);
            Case { pyr, con, name }
        })
        .collect()
}

fn opts(threads: usize, symmetric: bool) -> FmmOptions {
    FmmOptions {
        cfg: FmmConfig {
            p: 10,
            levels_override: Some(3),
            ..FmmConfig::default()
        },
        symmetric_p2p: symmetric,
        threads: Some(threads),
        ..FmmOptions::default()
    }
}

fn assert_bitwise(a: &[C64], b: &[C64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.re, y.re, "{what}: re diverged at particle {i}");
        assert_eq!(x.im, y.im, "{what}: im diverged at particle {i}");
    }
}

fn assert_counts_equal(a: &WorkCounts, b: &WorkCounts, what: &str) {
    assert_eq!(a.n, b.n, "{what}: n");
    assert_eq!(a.levels, b.levels, "{what}: levels");
    assert_eq!(a.p2p_pairs, b.p2p_pairs, "{what}: p2p_pairs");
    assert_eq!(a.p2l_pairs, b.p2l_pairs, "{what}: p2l_pairs");
    assert_eq!(a.m2p_pairs, b.m2p_pairs, "{what}: m2p_pairs");
    assert_eq!(a.p2m_particles, b.p2m_particles, "{what}: p2m_particles");
    assert_eq!(a.m2l_per_level, b.m2l_per_level, "{what}: m2l_per_level");
    assert_eq!(a.m2m_per_level, b.m2m_per_level, "{what}: m2m_per_level");
    assert_eq!(a.l2l_per_level, b.l2l_per_level, "{what}: l2l_per_level");
    assert_eq!(a.leaf_sizes, b.leaf_sizes, "{what}: leaf_sizes");
}

/// The worker-count axis: serial-width, even, odd, and oversubscribed
/// (more workers than cores — wakeup order is then at the OS's mercy,
/// which is exactly the schedule space the suite wants to sample).
fn thread_counts() -> Vec<usize> {
    vec![1, 2, 3, available_threads() + 2]
}

#[test]
fn fuzzed_schedules_are_bitwise_identical_to_the_pooled_engine() {
    for case in cases() {
        for symmetric in [true, false] {
            for t in thread_counts() {
                let pool = WorkerPool::new(t, false);
                let o = opts(t, symmetric);
                let (base, _, base_counts) =
                    evaluate_on_tree_pool(&case.pyr, &case.con, &o, &pool);
                // the production schedule plus jittered ones: several
                // seeds, short and long perturbation windows
                let mut schedules = vec![None];
                for seed in [1u64, 2, 0xDEAD_BEEF] {
                    schedules.push(Some(Jitter {
                        seed,
                        max_ns: 5_000,
                    }));
                    schedules.push(Some(Jitter {
                        seed: seed.wrapping_mul(31) + 7,
                        max_ns: 50_000,
                    }));
                }
                for jitter in schedules {
                    let what = format!(
                        "{} symmetric={symmetric} t={t} jitter={jitter:?}",
                        case.name
                    );
                    let (tg, times, counts) = evaluate_on_tree_taskgraph_seeded(
                        &case.pyr, &case.con, &o, &pool, jitter,
                    );
                    assert_bitwise(&base, &tg, &what);
                    assert_counts_equal(&base_counts, &counts, &what);
                    // the normalized phase times must stay a valid split
                    // of the wall clock under every schedule
                    assert!(times.total() >= 0.0, "{what}: negative total");
                    assert!(
                        times.0.iter().all(|s| s.is_finite() && *s >= 0.0),
                        "{what}: non-finite phase time {:?}",
                        times.0
                    );
                }
            }
        }
    }
}

#[test]
fn taskgraph_stays_anchored_to_the_serial_driver() {
    // the bitwise chain above is serial ↔ pooled ↔ taskgraph; this keeps
    // the anchor itself honest (≤ 1e-12 relative, the repo-wide parity
    // tolerance between the serial driver and the parallel engines)
    for case in cases() {
        let serial = fmm::evaluate_on_tree_serial(&case.pyr, &case.con, &opts(1, true)).0;
        let pool = WorkerPool::new(3, false);
        let (tg, _, _) =
            evaluate_on_tree_taskgraph_seeded(&case.pyr, &case.con, &opts(3, true), &pool, None);
        for (i, (a, b)) in serial.iter().zip(&tg).enumerate() {
            let scale = a.abs().max(1.0);
            assert!(
                (*a - *b).abs() <= 1e-12 * scale,
                "{}: particle {i}: serial {a:?} vs taskgraph {b:?}",
                case.name
            );
        }
    }
}

#[test]
fn repeated_fuzzed_runs_on_one_pool_are_self_consistent() {
    // same pool, same jitter seed, many runs: the engine must be a pure
    // function of its inputs (no state leaks through the accumulator
    // lease or the scheduler between evaluations)
    let case = &cases()[0];
    let pool = WorkerPool::new(3, false);
    let o = opts(3, true);
    let jitter = Some(Jitter {
        seed: 11,
        max_ns: 20_000,
    });
    let (first, _, _) =
        evaluate_on_tree_taskgraph_seeded(&case.pyr, &case.con, &o, &pool, jitter);
    for round in 0..4 {
        let (again, _, _) =
            evaluate_on_tree_taskgraph_seeded(&case.pyr, &case.con, &o, &pool, jitter);
        assert_bitwise(&first, &again, &format!("round {round}"));
    }
}
