//! The dispatch subsystem end to end: profile JSON round-trip (incl.
//! version/unknown-field rejection), deterministic selection,
//! `Engine::Auto` parity with the explicitly-chosen engines, and the
//! tolerance band of the a-priori `WorkCounts::estimate`.

use std::sync::Arc;

use fmm2d::batch::{self, BatchEngine, BatchOptions, BatchProblem};
use fmm2d::config::FmmConfig;
use fmm2d::dispatch::{
    evaluate_auto, CalibrationProfile, Dispatcher, Engine, EngineChoice, EngineRates,
    PooledRates, Problem, PROFILE_VERSION,
};
use fmm2d::fmm::{self, FmmOptions, WorkCounts, N_PHASES};
use fmm2d::util::rng::Pcg64;
use fmm2d::workload::{self, Distribution};

/// A hand-built profile with a serial engine, one pooled entry (4 workers,
/// 3.2× the throughput, a 0.5 ms dispatch overhead) — tiny problems pick
/// serial, large ones the pool, deterministically.
fn synthetic_profile() -> CalibrationProfile {
    CalibrationProfile {
        version: PROFILE_VERSION,
        serial: EngineRates {
            rates: [1.0e8; N_PHASES],
            overhead_s: 0.0,
        },
        pooled: vec![PooledRates {
            workers: 4,
            rates: EngineRates {
                rates: [3.2e8; N_PHASES],
                overhead_s: 5.0e-4,
            },
        }],
        // slightly below pooled, so the strict-less-than pick keeps the
        // pooled engine and the serial/pooled assertions below stay sharp
        taskgraph: vec![PooledRates {
            workers: 4,
            rates: EngineRates {
                rates: [3.0e8; N_PHASES],
                overhead_s: 5.0e-4,
            },
        }],
    }
}

// ---- profile persistence -----------------------------------------------

#[test]
fn profile_round_trips_through_json() {
    let p = synthetic_profile();
    let s = p.to_json_string();
    let back = CalibrationProfile::parse(&s).expect("own serialization must parse");
    assert_eq!(p, back);
}

#[test]
fn profile_rejects_version_mismatch() {
    let mut p = synthetic_profile();
    p.version = PROFILE_VERSION + 1;
    let err = CalibrationProfile::parse(&p.to_json_string())
        .unwrap_err()
        .to_string();
    assert!(err.contains("version"), "unexpected error: {err}");
}

#[test]
fn profile_rejects_unknown_fields() {
    let s = synthetic_profile().to_json_string();
    // a field from the future, injected at the top level
    let hacked = s.replacen('{', "{\"from_the_future\":1,", 1);
    let err = CalibrationProfile::parse(&hacked).unwrap_err().to_string();
    assert!(err.contains("unknown field"), "unexpected error: {err}");
    // and inside an engine-rates object
    let hacked = s.replacen("\"overhead_s\"", "\"surprise\":1,\"overhead_s\"", 1);
    let err = CalibrationProfile::parse(&hacked).unwrap_err().to_string();
    assert!(err.contains("unknown field"), "unexpected error: {err}");
}

#[test]
fn profile_save_load_cycle_on_disk() {
    let p = synthetic_profile();
    let dir = std::env::temp_dir().join("fmm2d-dispatch-test");
    let path = dir.join("profile.json");
    p.save(&path).expect("saving the profile");
    let d = Dispatcher::load(&path).expect("loading the saved profile");
    assert_eq!(d.profile, p);
    let _ = std::fs::remove_file(&path);
}

// ---- selection ----------------------------------------------------------

#[test]
fn same_profile_same_problems_same_choices() {
    let d = Dispatcher::new(synthetic_profile()).with_xla(false);
    let problems: Vec<Problem> = [(150, 1), (2_000, 2), (20_000, 4), (300_000, 6)]
        .iter()
        .map(|&(n, l)| Problem::new(n, l, 17, 0.5))
        .collect();
    let first: Vec<EngineChoice> = problems.iter().map(|p| d.select(p).choice).collect();
    let second: Vec<EngineChoice> = problems.iter().map(|p| d.select(p).choice).collect();
    assert_eq!(first, second);
    let g1 = d.select_group(&problems);
    let g2 = d.select_group(&problems);
    assert_eq!(g1.choice, g2.choice);
    assert_eq!(g1.predicted_s, g2.predicted_s);
}

#[test]
fn small_problems_stay_serial_large_ones_pool() {
    let d = Dispatcher::new(synthetic_profile()).with_xla(false);
    let small = d.select(&Problem::new(150, 1, 17, 0.5));
    assert_eq!(
        small.choice,
        EngineChoice::Serial,
        "a tiny problem must not pay the pool overhead: {small:?}"
    );
    let big = d.select(&Problem::new(200_000, 6, 17, 0.5));
    assert!(
        matches!(big.choice, EngineChoice::Pooled { workers: 4 }),
        "a large problem must use the pool: {big:?}"
    );
    assert!(big.cost.pooled_s < big.cost.serial_s);
}

#[test]
fn large_groups_go_to_xla_only_when_allowed() {
    // deliberately slow CPU rates: the simulated-GPU batch price wins
    let mut slow = synthetic_profile();
    slow.serial.rates = [1.0e6; N_PHASES];
    slow.pooled[0].rates.rates = [2.0e6; N_PHASES];
    slow.taskgraph[0].rates.rates = [2.0e6; N_PHASES];
    let members: Vec<Problem> = (0..32).map(|_| Problem::new(2_000, 2, 17, 0.5)).collect();
    let with_xla = Dispatcher::new(slow.clone()).with_xla(true);
    assert_eq!(with_xla.select_group(&members).choice, EngineChoice::Xla);
    let cpu_only = Dispatcher::new(slow).with_xla(false);
    assert_ne!(cpu_only.select_group(&members).choice, EngineChoice::Xla);
}

#[test]
fn engine_parses_through_the_single_from_str_impl() {
    assert_eq!("serial".parse::<Engine>().unwrap(), Engine::Serial);
    assert_eq!("parallel".parse::<Engine>().unwrap(), Engine::Parallel);
    assert_eq!("taskgraph".parse::<Engine>().unwrap(), Engine::TaskGraph);
    assert_eq!("xla".parse::<Engine>().unwrap(), Engine::Xla);
    assert_eq!("auto".parse::<Engine>().unwrap(), Engine::Auto);
    let err = "cuda".parse::<Engine>().unwrap_err().to_string();
    assert!(err.contains("serial|parallel|taskgraph|xla|auto"), "{err}");
    // the batch engine is the one-to-one image of the CLI selector
    assert_eq!(BatchEngine::from(Engine::Auto), BatchEngine::Auto);
    assert_eq!(BatchEngine::from(Engine::Serial), BatchEngine::Serial);
    assert_eq!(
        BatchEngine::from(Engine::TaskGraph),
        BatchEngine::TaskGraph
    );
}

// ---- Engine::Auto end to end -------------------------------------------

#[test]
fn auto_single_evaluation_matches_pooled() {
    let mut r = Pcg64::seed_from_u64(11);
    let (pts, gs) = workload::uniform_square(4_000, &mut r);
    let opts = FmmOptions {
        cfg: FmmConfig {
            p: 13,
            ..FmmConfig::default()
        },
        ..FmmOptions::default()
    };
    let d = Dispatcher::new(synthetic_profile()).with_xla(false);
    let (auto_out, decision) = evaluate_auto(&pts, &gs, &opts, &d).unwrap();
    assert!(decision.measured_s.unwrap() > 0.0);
    assert!(decision.predicted_s > 0.0);
    let pooled = fmm::evaluate(&pts, &gs, &opts).unwrap();
    for (a, b) in auto_out.potentials.iter().zip(&pooled.potentials) {
        assert!(
            (*a - *b).abs() <= 1e-12 * a.abs().max(1.0),
            "auto {a:?} vs pooled {b:?}"
        );
    }
}

#[test]
fn auto_batch_matches_parallel_and_carries_a_report() {
    let mut r = Pcg64::seed_from_u64(12);
    let problems: Vec<BatchProblem> = [800usize, 2_200, 900, 2_400]
        .iter()
        .map(|&n| {
            let (points, gammas) = workload::uniform_square(n, &mut r);
            BatchProblem { points, gammas }
        })
        .collect();
    let fmm_opts = FmmOptions {
        cfg: FmmConfig {
            p: 10,
            ..FmmConfig::default()
        },
        threads: Some(2),
        ..FmmOptions::default()
    };
    let auto = batch::run(
        &problems,
        &BatchOptions {
            fmm: fmm_opts.clone(),
            engine: BatchEngine::Auto,
            dispatcher: Some(Arc::new(
                Dispatcher::new(synthetic_profile()).with_xla(false),
            )),
            ..BatchOptions::default()
        },
    )
    .unwrap();
    let parallel = batch::run(
        &problems,
        &BatchOptions {
            fmm: fmm_opts,
            engine: BatchEngine::Parallel,
            ..BatchOptions::default()
        },
    )
    .unwrap();
    assert!(parallel.report.is_none(), "explicit engines carry no report");
    let report = auto.report.expect("auto batches carry a dispatch report");
    assert_eq!(report.decisions.len(), auto.stats.n_groups);
    for d in &report.decisions {
        assert!(d.measured_s.is_some(), "every group must be timed: {d:?}");
        assert_ne!(d.choice, EngineChoice::Xla, "CPU-only build chose XLA");
    }
    let rendered = report.render();
    assert!(
        rendered.contains("serial") || rendered.contains("pooled"),
        "render must show the choice: {rendered}"
    );
    assert_eq!(auto.stats.dispatches, auto.stats.n_groups);
    for (a, b) in auto.potentials.iter().zip(&parallel.potentials) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!(
                (*x - *y).abs() <= 1e-12 * x.abs().max(1.0),
                "auto {x:?} vs parallel {y:?}"
            );
        }
    }
}

// ---- WorkCounts::estimate tolerance band --------------------------------

fn measured_counts(dist: Distribution, n: usize, p: usize, seed: u64) -> WorkCounts {
    let mut r = Pcg64::seed_from_u64(seed);
    let (pts, gs) = dist.generate(n, &mut r);
    let out = fmm::evaluate(
        &pts,
        &gs,
        &FmmOptions {
            cfg: FmmConfig {
                p,
                ..FmmConfig::default()
            },
            threads: Some(1),
            ..FmmOptions::default()
        },
    )
    .unwrap();
    out.counts
}

fn assert_band(what: &str, estimated: usize, measured: usize, lo: f64, hi: f64) {
    let ratio = estimated as f64 / measured.max(1) as f64;
    assert!(
        ratio >= lo && ratio <= hi,
        "{what}: estimate {estimated} vs measured {measured} (ratio {ratio:.3} \
         outside [{lo}, {hi}])"
    );
}

#[test]
fn estimate_tracks_measured_counts_on_uniform_points() {
    let n = 4_000;
    let m = measured_counts(Distribution::Uniform, n, 10, 21);
    let e = WorkCounts::estimate(n, m.levels, 10, 0.5);
    // structure-exact quantities
    assert_eq!(e.p2m_particles, m.p2m_particles);
    assert_eq!(e.m2m_per_level, m.m2m_per_level);
    assert_eq!(e.l2l_per_level, m.l2l_per_level);
    assert_eq!(e.leaf_sizes.len(), m.leaf_sizes.len());
    assert_eq!(
        e.leaf_sizes.iter().map(|&x| x as usize).sum::<usize>(),
        m.leaf_sizes.iter().map(|&x| x as usize).sum::<usize>()
    );
    // geometry-dependent quantities: tight band on uniform inputs
    assert_band(
        "m2l (uniform)",
        e.m2l_per_level.iter().sum(),
        m.m2l_per_level.iter().sum(),
        0.5,
        2.0,
    );
    assert_band("p2p (uniform)", e.p2p_pairs, m.p2p_pairs, 0.5, 2.0);
    assert_band(
        "checks (uniform)",
        e.connect_checks,
        m.connect_checks,
        0.5,
        2.0,
    );
}

#[test]
fn estimate_tracks_measured_counts_on_clustered_points() {
    let n = 4_000;
    let m = measured_counts(Distribution::Normal { sigma: 0.1 }, n, 10, 22);
    let e = WorkCounts::estimate(n, m.levels, 10, 0.5);
    assert_eq!(e.p2m_particles, m.p2m_particles);
    assert_eq!(e.m2m_per_level, m.m2m_per_level);
    assert_eq!(e.l2l_per_level, m.l2l_per_level);
    // clustering skews the boxes, so the bands are wider — but an
    // order-of-magnitude regression still fails
    assert_band(
        "m2l (clustered)",
        e.m2l_per_level.iter().sum(),
        m.m2l_per_level.iter().sum(),
        0.1,
        8.0,
    );
    assert_band("p2p (clustered)", e.p2p_pairs, m.p2p_pairs, 0.1, 8.0);
    assert_band(
        "checks (clustered)",
        e.connect_checks,
        m.connect_checks,
        0.1,
        8.0,
    );
}
