//! End-to-end over the PJRT runtime: the three layers composed.
//!
//! Rust builds the adaptive tree (topological phase), packs it, executes
//! the AOT-compiled fused FMM artifact (whose hot spots are the Pallas
//! kernels), and the result is checked against both direct summation and
//! the serial Rust FMM — "identical accuracy from the two codes" is the
//! paper's own headline property (§4.5).
//!
//! Requires `make artifacts` (skipped with a notice when absent, so plain
//! `cargo test` works in a fresh checkout) and a build with the `pjrt`
//! feature (the whole file is compiled out otherwise).

#![cfg(feature = "pjrt")]

use fmm2d::complex::C64;
use fmm2d::config::FmmConfig;
use fmm2d::connectivity::Connectivity;
use fmm2d::direct;
use fmm2d::expansion::Kernel;
use fmm2d::fmm::{self, FmmOptions};
use fmm2d::runtime::Runtime;
use fmm2d::tree::Pyramid;
use fmm2d::util::rng::Pcg64;
use fmm2d::util::stats::max_rel_error;
use fmm2d::workload;

fn runtime_or_skip() -> Option<Runtime> {
    let rt = Runtime::new(None).expect("PJRT CPU client");
    if rt.available().is_empty() {
        eprintln!(
            "SKIP: no artifacts in {} — run `make artifacts`",
            rt.artifact_dir().display()
        );
        return None;
    }
    Some(rt)
}

fn rel_err(a: &[C64], b: &[C64]) -> f64 {
    let av: Vec<f64> = a.iter().map(|z| z.abs()).collect();
    let bv: Vec<f64> = b.iter().map(|z| z.abs()).collect();
    max_rel_error(&av, &bv, 1e-12)
}

#[test]
fn fmm_artifact_matches_direct_and_serial() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut r = Pcg64::seed_from_u64(2024);
    let (pts, gs) = workload::uniform_square(3000, &mut r);

    // topological phase in Rust (L3)
    let pyr = Pyramid::build(&pts, &gs, 3).unwrap();
    let con = Connectivity::build(&pyr, 0.5);

    // computational phase through PJRT (L2 + L1)
    let exe = rt.load("fmm_l3_p17").expect("artifact fmm_l3_p17");
    let (pot, stats) = exe.run_fmm(&pyr, &con).expect("artifact execution");
    assert!(stats.execute_s > 0.0);

    // against direct summation: p=17 ⇒ TOL ≈ 1e-6 (paper §5.1)
    let exact = direct::eval_symmetric(Kernel::Harmonic, &pts, &gs);
    let err = rel_err(&pot, &exact);
    assert!(err < 1e-5, "XLA path vs direct: {err:e}");

    // against the serial CPU driver: same algorithm, same tree
    let opts = FmmOptions {
        cfg: FmmConfig {
            p: 17,
            levels_override: Some(3),
            ..FmmConfig::default()
        },
        ..Default::default()
    };
    let (phi_leaf, _, _) = fmm::evaluate_on_tree(&pyr, &con, &opts);
    let serial = pyr.unpermute(&phi_leaf);
    let agree = rel_err(&pot, &serial);
    assert!(agree < 1e-9, "XLA vs serial Rust disagree: {agree:e}");
}

#[test]
fn fmm_artifact_nonuniform_distribution() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut r = Pcg64::seed_from_u64(7);
    let (pts, gs) = workload::normal_cloud(2500, 0.1, &mut r);
    let pyr = Pyramid::build(&pts, &gs, 3).unwrap();
    let con = Connectivity::build(&pyr, 0.5);
    // adaptive shortcut lists exercised on clustered input
    let exe = rt.load("fmm_l3_p17").unwrap();
    let (pot, _) = exe.run_fmm(&pyr, &con).expect("artifact execution");
    let exact = direct::eval_symmetric(Kernel::Harmonic, &pts, &gs);
    let err = rel_err(&pot, &exact);
    assert!(err < 2e-5, "normal cloud: {err:e}");
}

#[test]
fn small_artifact_l2_p8() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut r = Pcg64::seed_from_u64(11);
    let (pts, gs) = workload::uniform_square(400, &mut r);
    let pyr = Pyramid::build(&pts, &gs, 2).unwrap();
    let con = Connectivity::build(&pyr, 0.5);
    let exe = rt.load("fmm_l2_p8").unwrap();
    let (pot, _) = exe.run_fmm(&pyr, &con).unwrap();
    let exact = direct::eval_symmetric(Kernel::Harmonic, &pts, &gs);
    // p=8 ⇒ θ^8 ≈ 4e-3 geometric bound; observed much better on uniform
    let err = rel_err(&pot, &exact);
    assert!(err < 1e-2, "p=8: {err:e}");
}

#[test]
fn direct_artifact_matches_cpu() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let exe = rt.load("direct_n2048").unwrap();
    let n = exe.meta.n_direct;
    let mut r = Pcg64::seed_from_u64(3);
    let (pts, gs) = workload::uniform_square(n, &mut r);
    let (pot, _) = exe.run_direct(&pts, &gs).unwrap();
    let exact = direct::eval_symmetric(Kernel::Harmonic, &pts, &gs);
    let err = rel_err(&pot, &exact);
    assert!(err < 1e-10, "direct artifact: {err:e}");
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = rt.load("fmm_l2_p8").unwrap();
    let b = rt.load("fmm_l2_p8").unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b), "second load must hit the cache");
}

#[test]
fn pad_overflow_reports_actionable_error() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // 2-level tree fed to the 3-level artifact: must fail with a clear error
    let mut r = Pcg64::seed_from_u64(5);
    let (pts, gs) = workload::uniform_square(500, &mut r);
    let pyr = Pyramid::build(&pts, &gs, 2).unwrap();
    let con = Connectivity::build(&pyr, 0.5);
    let exe = rt.load("fmm_l3_p17").unwrap();
    let err = exe.run_fmm(&pyr, &con).unwrap_err().to_string();
    assert!(err.contains("levels"), "got: {err}");
}

#[test]
fn pallas_variant_matches_jnp_variant() {
    // the TPU-design artifact (hot spots through the L1 Pallas kernels)
    // and the fast jnp-lowered artifact are numerically identical
    let Some(mut rt) = runtime_or_skip() else { return };
    if !rt.available().contains(&"fmm_l2_p8_pallas".to_string()) {
        eprintln!("SKIP: pallas variant not emitted");
        return;
    }
    let mut r = Pcg64::seed_from_u64(31);
    let (pts, gs) = workload::uniform_square(420, &mut r);
    let pyr = Pyramid::build(&pts, &gs, 2).unwrap();
    let con = Connectivity::build(&pyr, 0.5);
    let a = rt.load("fmm_l2_p8").unwrap();
    let b = rt.load("fmm_l2_p8_pallas").unwrap();
    let (pa, _) = a.run_fmm(&pyr, &con).unwrap();
    let (pb, _) = b.run_fmm(&pyr, &con).unwrap();
    for (x, y) in pa.iter().zip(&pb) {
        assert!((*x - *y).abs() < 1e-11 * x.abs().max(1.0));
    }
}

#[test]
fn batched_group_matches_single_runs() {
    // batched dispatch path: needs an artifact emitted with a `batch`
    // manifest field (skipped gracefully until aot.py emits one)
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut r = Pcg64::seed_from_u64(41);
    let (pa, ga) = workload::uniform_square(500, &mut r);
    let (pb, gb) = workload::uniform_square(700, &mut r);
    let pyr_a = Pyramid::build(&pa, &ga, 2).unwrap();
    let con_a = Connectivity::build(&pyr_a, 0.5);
    let pyr_b = Pyramid::build(&pb, &gb, 2).unwrap();
    let con_b = Connectivity::build(&pyr_b, 0.5);
    let group: Vec<(&Pyramid, &Connectivity)> = vec![(&pyr_a, &con_a), (&pyr_b, &con_b)];
    let Ok(exe) = rt.fmm_artifact_for_group(&group) else {
        eprintln!("SKIP: no batched artifact available — emit one via aot.py");
        return;
    };
    let (pots, stats) = exe.run_fmm_group(&group).expect("batched execution");
    assert_eq!(pots.len(), 2);
    assert!(stats.execute_s > 0.0);
    for ((pyr, con), pot) in group.iter().zip(&pots) {
        let single = rt.fmm_artifact_for_tree(pyr, con).unwrap();
        let (expect, _) = single.run_fmm(pyr, con).unwrap();
        let err = rel_err(pot, &expect);
        assert!(err < 1e-11, "batched vs single-problem run: {err:e}");
    }
}
