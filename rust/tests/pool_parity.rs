//! Parity and lifecycle contract of the persistent worker pool engine
//! (`fmm::parallel::evaluate_on_tree_pool`, `util::pool::WorkerPool`):
//!
//! * potentials ≤ 1e-12 relative error vs the serial driver and
//!   *identical* `WorkCounts`, across thread counts 1 / 2 / odd / > cores;
//! * bitwise identity with the scoped spawn-per-phase engine at the same
//!   worker count (same sharding, same reduction order);
//! * one pool reused across ≥ 3 consecutive heterogeneous problems (and a
//!   batch run) without rebuilding;
//! * drop-then-rebuild: shutdown joins every worker (none leaked/parked),
//!   and a fresh pool serves correctly afterwards.

use std::sync::Arc;

use fmm2d::config::FmmConfig;
use fmm2d::expansion::Kernel;
use fmm2d::fmm::{
    self, evaluate_on_tree_serial,
    parallel::{evaluate_on_tree_parallel, evaluate_on_tree_pool},
    FmmOptions, WorkCounts,
};
use fmm2d::topology::{self, TopologyOptions};
use fmm2d::util::pool::WorkerPool;
use fmm2d::util::rng::Pcg64;
use fmm2d::workload::Distribution;

fn assert_counts_identical(a: &WorkCounts, b: &WorkCounts, what: &str) {
    assert_eq!(a.n, b.n, "{what}: n");
    assert_eq!(a.levels, b.levels, "{what}: levels");
    assert_eq!(a.p, b.p, "{what}: p");
    assert_eq!(a.leaf_sizes, b.leaf_sizes, "{what}: leaf_sizes");
    assert_eq!(a.m2l_per_level, b.m2l_per_level, "{what}: m2l_per_level");
    assert_eq!(a.m2m_per_level, b.m2m_per_level, "{what}: m2m_per_level");
    assert_eq!(a.l2l_per_level, b.l2l_per_level, "{what}: l2l_per_level");
    assert_eq!(a.p2p_pairs, b.p2p_pairs, "{what}: p2p_pairs");
    assert_eq!(a.p2p_src_per_box, b.p2p_src_per_box, "{what}: p2p_src_per_box");
    assert_eq!(a.p2l_pairs, b.p2l_pairs, "{what}: p2l_pairs");
    assert_eq!(a.m2p_pairs, b.m2p_pairs, "{what}: m2p_pairs");
    assert_eq!(a.p2m_particles, b.p2m_particles, "{what}: p2m_particles");
    assert_eq!(a.connect_checks, b.connect_checks, "{what}: connect_checks");
}

fn opts_with(p: usize, levels: usize, threads: usize) -> FmmOptions {
    FmmOptions {
        cfg: FmmConfig {
            p,
            levels_override: Some(levels),
            ..FmmConfig::default()
        },
        threads: Some(threads),
        ..FmmOptions::default()
    }
}

#[test]
fn pool_engine_matches_serial_across_thread_counts() {
    let cores = fmm2d::util::threadpool::available_threads();
    let mut r = Pcg64::seed_from_u64(41);
    let (pts, gs) = Distribution::Normal { sigma: 0.1 }.generate(2500, &mut r);
    let topo = topology::build(&pts, &gs, 3, &TopologyOptions::serial(0.5)).unwrap();
    let (pyr, con) = (&topo.pyramid, &topo.connectivity);
    let serial_opts = opts_with(13, 3, 1);
    let (serial, _, sc) = evaluate_on_tree_serial(pyr, con, &serial_opts);
    for nt in [1usize, 2, 3, cores + 2] {
        let pool = WorkerPool::new(nt, false);
        let opts = opts_with(13, 3, nt);
        let (pooled, pt, pc) = evaluate_on_tree_pool(pyr, con, &opts, &pool);
        assert_eq!(pooled.len(), serial.len());
        for (i, (a, b)) in serial.iter().zip(&pooled).enumerate() {
            assert!(
                (*a - *b).abs() <= 1e-12 * a.abs().max(1.0),
                "t={nt}: potential {i} diverged: {a:?} vs {b:?}"
            );
        }
        assert_counts_identical(&sc, &pc, &format!("pool t={nt}"));
        assert!(pt.total() > 0.0, "t={nt}: no time recorded");
        // and bitwise identity with the scoped engine at the same count
        let (scoped, _, _) = evaluate_on_tree_parallel(pyr, con, &opts, nt.min(pool.n_workers()));
        for (a, b) in scoped.iter().zip(&pooled) {
            assert_eq!(a.re, b.re, "t={nt}: pooled != scoped bitwise");
            assert_eq!(a.im, b.im, "t={nt}: pooled != scoped bitwise");
        }
    }
}

#[test]
fn one_pool_serves_consecutive_heterogeneous_problems() {
    // one pool, ≥3 problems with different sizes, orders, depths,
    // distributions and kernels — scratch/accumulator reuse must never
    // leak state from one problem into the next
    let pool = Arc::new(WorkerPool::new(3, false));
    let cases: [(usize, usize, usize, Distribution, Kernel, bool); 4] = [
        (1200, 10, 2, Distribution::Uniform, Kernel::Harmonic, true),
        (3000, 17, 3, Distribution::Normal { sigma: 0.1 }, Kernel::Harmonic, false),
        (800, 8, 2, Distribution::Layer { sigma: 0.05 }, Kernel::Harmonic, true),
        (1600, 12, 2, Distribution::Uniform, Kernel::Log, false),
    ];
    for (seed, &(n, p, levels, dist, kernel, sym)) in cases.iter().enumerate() {
        let mut r = Pcg64::seed_from_u64(100 + seed as u64);
        let (pts, mut gs) = dist.generate(n, &mut r);
        if kernel == Kernel::Log {
            for g in gs.iter_mut() {
                g.im = 0.0; // log potential: real strengths
            }
        }
        let topo = topology::build(&pts, &gs, levels, &TopologyOptions::serial(0.5)).unwrap();
        let opts = FmmOptions {
            kernel,
            symmetric_p2p: sym,
            pool: Some(Arc::clone(&pool)),
            ..opts_with(p, levels, 3)
        };
        let (serial, _, _) = evaluate_on_tree_serial(&topo.pyramid, &topo.connectivity, &opts);
        let (pooled, _, _) =
            evaluate_on_tree_pool(&topo.pyramid, &topo.connectivity, &opts, &pool);
        for (i, (a, b)) in serial.iter().zip(&pooled).enumerate() {
            assert!(
                (*a - *b).abs() <= 1e-12 * a.abs().max(1.0),
                "case {seed}: potential {i} diverged"
            );
        }
    }
}

#[test]
fn full_evaluate_through_an_explicit_pool() {
    // the user-facing entry point with FmmOptions::pool set: topology and
    // compute both run on the owned pool, results in caller order
    let pool = Arc::new(WorkerPool::new(4, false));
    let mut r = Pcg64::seed_from_u64(7);
    let (pts, gs) = Distribution::Uniform.generate(3000, &mut r);
    let serial = fmm::evaluate(
        &pts,
        &gs,
        &FmmOptions {
            threads: Some(1),
            ..FmmOptions::default()
        },
    )
    .unwrap();
    let pooled = fmm::evaluate(
        &pts,
        &gs,
        &FmmOptions {
            threads: Some(4),
            pool: Some(Arc::clone(&pool)),
            ..FmmOptions::default()
        },
    )
    .unwrap();
    assert_eq!(serial.potentials.len(), pooled.potentials.len());
    for (a, b) in serial.potentials.iter().zip(&pooled.potentials) {
        assert!((*a - *b).abs() <= 1e-12 * a.abs().max(1.0));
    }
    assert_eq!(serial.counts.p2p_pairs, pooled.counts.p2p_pairs);
    // the pool-built topology is the same tree the serial path built
    assert_eq!(serial.counts.connect_checks, pooled.counts.connect_checks);
}

#[test]
fn batch_runs_on_an_explicit_pool() {
    use fmm2d::batch::{self, BatchEngine, BatchOptions, BatchProblem};

    let pool = Arc::new(WorkerPool::new(3, false));
    let mut r = Pcg64::seed_from_u64(19);
    let problems: Vec<BatchProblem> = [900usize, 2400, 1000, 2600]
        .iter()
        .map(|&n| {
            let (points, gammas) = Distribution::Uniform.generate(n, &mut r);
            BatchProblem { points, gammas }
        })
        .collect();
    let opts = BatchOptions {
        fmm: FmmOptions {
            cfg: FmmConfig {
                p: 10,
                ..FmmConfig::default()
            },
            threads: Some(3),
            pool: Some(Arc::clone(&pool)),
            ..FmmOptions::default()
        },
        engine: BatchEngine::Parallel,
        max_group: 0,
        ..BatchOptions::default()
    };
    let out = batch::run(&problems, &opts).unwrap();
    assert_eq!(out.potentials.len(), problems.len());
    for (pr, phi) in problems.iter().zip(&out.potentials) {
        let seq = fmm::evaluate(
            &pr.points,
            &pr.gammas,
            &FmmOptions {
                threads: Some(1),
                ..opts.fmm.clone()
            },
        )
        .unwrap();
        for (a, b) in phi.iter().zip(&seq.potentials) {
            assert!((*a - *b).abs() <= 1e-12 * a.abs().max(1.0));
        }
    }
}

#[test]
fn drop_then_rebuild_shuts_down_cleanly() {
    let mut r = Pcg64::seed_from_u64(23);
    let (pts, gs) = Distribution::Uniform.generate(1500, &mut r);
    let topo = topology::build(&pts, &gs, 2, &TopologyOptions::serial(0.5)).unwrap();
    let opts = opts_with(9, 2, 3);

    let pool = WorkerPool::new(3, false);
    let (first, _, _) = evaluate_on_tree_pool(&topo.pyramid, &topo.connectivity, &opts, &pool);
    // shutdown joins every worker: none leaked, none left parked
    assert_eq!(pool.shutdown_and_count(), 0, "workers leaked past shutdown");

    // a rebuilt pool serves the same problem identically
    let pool2 = WorkerPool::new(3, false);
    let (second, _, _) = evaluate_on_tree_pool(&topo.pyramid, &topo.connectivity, &opts, &pool2);
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a.re, b.re);
        assert_eq!(a.im, b.im);
    }
    assert_eq!(pool2.shutdown_and_count(), 0);
}

#[test]
fn pinned_pool_parity() {
    // --pin is best-effort and must never change results
    let mut r = Pcg64::seed_from_u64(29);
    let (pts, gs) = Distribution::Uniform.generate(1200, &mut r);
    let topo = topology::build(&pts, &gs, 2, &TopologyOptions::serial(0.5)).unwrap();
    let opts = opts_with(11, 2, 2);
    let unpinned = WorkerPool::new(2, false);
    let pinned = WorkerPool::new(2, true);
    let (a, _, _) = evaluate_on_tree_pool(&topo.pyramid, &topo.connectivity, &opts, &unpinned);
    let (b, _, _) = evaluate_on_tree_pool(&topo.pyramid, &topo.connectivity, &opts, &pinned);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.re, y.re);
        assert_eq!(x.im, y.im);
    }
}
