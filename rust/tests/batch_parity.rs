//! Batch execution parity: potentials from batched runs must match
//! sequential per-problem runs to ≤ 1e-12 relative error, across mixed
//! problem sizes, both CPU engines, and shape-heterogeneous batches that
//! force multiple dispatch groups.

use fmm2d::batch::{self, BatchEngine, BatchOptions, BatchProblem};
use fmm2d::config::FmmConfig;
use fmm2d::fmm::{self, FmmOptions};
use fmm2d::util::rng::Pcg64;
use fmm2d::workload;

fn problems_of(sizes: &[usize], seed: u64) -> Vec<BatchProblem> {
    let mut r = Pcg64::seed_from_u64(seed);
    sizes
        .iter()
        .map(|&n| {
            let (points, gammas) = workload::uniform_square(n, &mut r);
            BatchProblem { points, gammas }
        })
        .collect()
}

fn fmm_opts(p: usize, threads: Option<usize>) -> FmmOptions {
    FmmOptions {
        cfg: FmmConfig {
            p,
            ..FmmConfig::default()
        },
        threads,
        ..FmmOptions::default()
    }
}

/// Assert per-problem parity of a batched run against sequential
/// single-problem serial-driver evaluations.
fn assert_parity(problems: &[BatchProblem], opts: &BatchOptions) -> batch::BatchOutput {
    let out = batch::run(problems, opts).expect("CPU batch engines cannot fail");
    assert_eq!(out.potentials.len(), problems.len());
    for (i, pr) in problems.iter().enumerate() {
        let seq = fmm::evaluate(
            &pr.points,
            &pr.gammas,
            &FmmOptions {
                threads: Some(1),
                ..opts.fmm.clone()
            },
        )
        .unwrap();
        assert_eq!(out.potentials[i].len(), pr.points.len());
        for (a, b) in out.potentials[i].iter().zip(&seq.potentials) {
            assert!(
                (*a - *b).abs() <= 1e-12 * a.abs().max(1.0),
                "problem {i}: batched {a:?} vs sequential {b:?}"
            );
        }
    }
    out
}

// N_d = 45 ⇒ Eq. (5.2): sizes ≤ ~1100 build 2-level trees, the larger
// ones 3-level trees — a mixed batch always spans two shape classes.
const MIXED_SIZES: [usize; 6] = [600, 2200, 700, 2400, 650, 3000];

#[test]
fn parallel_engine_parity_on_heterogeneous_batch() {
    let problems = problems_of(&MIXED_SIZES, 1);
    let out = assert_parity(
        &problems,
        &BatchOptions {
            fmm: fmm_opts(12, Some(3)),
            engine: BatchEngine::Parallel,
            max_group: 0,
            ..BatchOptions::default()
        },
    );
    assert!(
        out.stats.n_groups >= 2,
        "mixed sizes must form multiple groups, got {}",
        out.stats.n_groups
    );
    assert_eq!(out.stats.dispatches, out.stats.n_groups);
    assert_eq!(out.counts.n, MIXED_SIZES.iter().sum::<usize>());
}

#[test]
fn serial_engine_parity_on_heterogeneous_batch() {
    let problems = problems_of(&MIXED_SIZES, 2);
    let out = assert_parity(
        &problems,
        &BatchOptions {
            fmm: fmm_opts(10, Some(1)),
            engine: BatchEngine::Serial,
            max_group: 0,
            ..BatchOptions::default()
        },
    );
    assert!(out.stats.n_groups >= 2);
}

#[test]
fn parity_survives_group_splitting() {
    // --batch-size 2 forces the planner to split shape classes; results
    // must be identical regardless of dispatch width
    let problems = problems_of(&MIXED_SIZES, 3);
    let narrow = assert_parity(
        &problems,
        &BatchOptions {
            fmm: fmm_opts(10, Some(2)),
            engine: BatchEngine::Parallel,
            max_group: 2,
            ..BatchOptions::default()
        },
    );
    let wide = batch::run(
        &problems,
        &BatchOptions {
            fmm: fmm_opts(10, Some(2)),
            engine: BatchEngine::Parallel,
            max_group: 0,
            ..BatchOptions::default()
        },
    )
    .unwrap();
    assert!(narrow.stats.n_groups > wide.stats.n_groups);
    for (a, b) in narrow.potentials.iter().zip(&wide.potentials) {
        for (x, y) in a.iter().zip(b) {
            assert!((*x - *y).abs() <= 1e-12 * x.abs().max(1.0));
        }
    }
}

#[test]
fn aggregated_counts_are_the_sum_of_members() {
    let problems = problems_of(&[800, 900, 2400], 4);
    let out = batch::run(
        &problems,
        &BatchOptions {
            fmm: fmm_opts(10, Some(2)),
            engine: BatchEngine::Parallel,
            max_group: 0,
            ..BatchOptions::default()
        },
    )
    .unwrap();
    let mut n = 0;
    let mut p2p = 0;
    for pr in &problems {
        let seq = fmm::evaluate(&pr.points, &pr.gammas, &fmm_opts(10, Some(1))).unwrap();
        n += seq.counts.n;
        p2p += seq.counts.p2p_pairs;
    }
    assert_eq!(out.counts.n, n);
    assert_eq!(out.counts.p2p_pairs, p2p);
    assert_eq!(out.counts.p2m_particles, n);
    // per-leaf vectors concatenate across the batch
    assert_eq!(
        out.counts.leaf_sizes.iter().map(|&x| x as usize).sum::<usize>(),
        n
    );
}

#[test]
fn directed_p2p_batches_identically() {
    // the directed (GPU-layout) near-field path through the batch engine
    let problems = problems_of(&[700, 2300], 5);
    let opts = BatchOptions {
        fmm: FmmOptions {
            symmetric_p2p: false,
            ..fmm_opts(10, Some(2))
        },
        engine: BatchEngine::Parallel,
        max_group: 0,
        ..BatchOptions::default()
    };
    assert_parity(&problems, &opts);
}
