//! Property-based tests over the whole coordinator (seeded generators via
//! `util::prop`; set FMM2D_PROP_CASES to widen coverage in CI).

use fmm2d::complex::C64;
use fmm2d::config::FmmConfig;
use fmm2d::connectivity::{is_symmetric, Connectivity};
use fmm2d::direct;
use fmm2d::expansion::shifts::{l2l, m2l, m2m_scaled};
use fmm2d::expansion::{l2p, m2p, p2m, Coeffs, Kernel};
use fmm2d::fmm::{evaluate, FmmOptions};
use fmm2d::geometry::theta_criterion;
use fmm2d::tree::{boxes_at_level, Pyramid};
use fmm2d::util::prop::{self, Config};
use fmm2d::util::rng::Pcg64;
use fmm2d::workload::Distribution;

fn random_cloud(r: &mut Pcg64) -> (Vec<C64>, Vec<C64>, usize) {
    let dist = match r.below(3) {
        0 => Distribution::Uniform,
        1 => Distribution::Normal {
            sigma: 0.02 + 0.2 * r.uniform(),
        },
        _ => Distribution::Layer {
            sigma: 0.02 + 0.1 * r.uniform(),
        },
    };
    let levels = 1 + r.below(3) as usize;
    let n = boxes_at_level(levels) * (2 + r.below(40) as usize);
    let (pts, gs) = dist.generate(n, r);
    (pts, gs, levels)
}

#[test]
fn prop_tree_partitions_particles() {
    prop::forall(
        Config { cases: 24, ..Default::default() },
        |r| random_cloud(r),
        |(pts, gs, levels)| {
            let pyr = Pyramid::build(pts, gs, *levels).unwrap();
            // every particle in exactly one leaf, inside its rect
            let mut seen = vec![false; pts.len()];
            for b in 0..pyr.n_leaves() {
                let rect = pyr.rects[*levels][b];
                for q in pyr.leaf(b) {
                    if seen[q.orig as usize] {
                        return Err(format!("particle {} twice", q.orig));
                    }
                    seen[q.orig as usize] = true;
                    if !rect.contains(q.pos) {
                        return Err(format!("particle {} outside rect", q.orig));
                    }
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err("lost particles".into());
            }
            // balance: sizes within the repeated-halving envelope
            let sizes: Vec<usize> = (0..pyr.n_leaves()).map(|b| pyr.leaf(b).len()).collect();
            let (lo, hi) = (
                *sizes.iter().min().unwrap(),
                *sizes.iter().max().unwrap(),
            );
            if hi - lo > 2 * *levels {
                return Err(format!("unbalanced: {lo}..{hi}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_connectivity_invariants() {
    prop::forall(
        Config { cases: 16, ..Default::default() },
        |r| random_cloud(r),
        |(pts, gs, levels)| {
            let pyr = Pyramid::build(pts, gs, *levels).unwrap();
            let con = Connectivity::build(&pyr, 0.5);
            // P2P symmetry
            if !is_symmetric(&con.near) {
                return Err("near field not symmetric".into());
            }
            // self in near list
            for b in 0..pyr.n_leaves() {
                if !con.near.sources(b).contains(&(b as u32)) {
                    return Err(format!("box {b} missing self"));
                }
            }
            // θ-criterion for all weak pairs at all levels
            for l in 1..=*levels {
                for b in 0..boxes_at_level(l) {
                    for &s in con.weak[l].sources(b) {
                        let (ra, rs) = (
                            pyr.rects[l][b].radius(),
                            pyr.rects[l][s as usize].radius(),
                        );
                        let d = (pyr.rects[l][b].center()
                            - pyr.rects[l][s as usize].center())
                        .abs();
                        if !theta_criterion(ra, rs, d, 0.5) {
                            return Err(format!("weak pair ({b},{s})@{l} violates θ"));
                        }
                    }
                }
            }
            // P2L/M2P duality
            let mut p2l: Vec<(u32, u32)> = (0..pyr.n_leaves())
                .flat_map(|b| {
                    con.p2l.sources(b).iter().map(move |&s| (b as u32, s))
                })
                .collect();
            let mut m2p: Vec<(u32, u32)> = (0..pyr.n_leaves())
                .flat_map(|b| {
                    con.m2p.sources(b).iter().map(move |&s| (s, b as u32))
                })
                .collect();
            p2l.sort_unstable();
            m2p.sort_unstable();
            if p2l != m2p {
                return Err("P2L/M2P not dual".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fmm_error_within_geometric_bound() {
    // Eq. (5.3) error stays under a comfortable multiple of θ^p across
    // random clouds, orders and depths.
    prop::forall(
        Config { cases: 10, ..Default::default() },
        |r| {
            let (pts, gs, levels) = random_cloud(r);
            let p = 6 + r.below(18) as usize;
            (pts, gs, levels, p)
        },
        |(pts, gs, levels, p)| {
            let opts = FmmOptions {
                cfg: FmmConfig {
                    p: *p,
                    levels_override: Some(*levels),
                    ..FmmConfig::default()
                },
                ..Default::default()
            };
            let out = evaluate(pts, gs, &opts).unwrap();
            let exact = direct::eval_symmetric(Kernel::Harmonic, pts, gs);
            let scale = exact.iter().map(|z| z.abs()).fold(0.0, f64::max);
            let err = out
                .potentials
                .iter()
                .zip(&exact)
                .map(|(a, e)| (*a - *e).abs())
                .fold(0.0f64, f64::max)
                / scale;
            let bound = 60.0 * 0.5f64.powi(*p as i32);
            if err > bound {
                return Err(format!("err {err:e} > bound {bound:e} (p={p})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_translation_identities() {
    // M2M path-independence, M2L+L2L commutation with evaluation, and
    // M2P consistency with the shifted expansion — on random coefficients.
    prop::forall(
        Config { cases: 40, ..Default::default() },
        |r| {
            let p = 10 + r.below(22) as usize;
            let coeffs: Vec<C64> = std::iter::once(C64::new(0.0, 0.0))
                .chain((0..p).map(|_| {
                    C64::new(r.uniform_in(-1.0, 1.0), r.uniform_in(-1.0, 1.0))
                }))
                .collect();
            // well-separated geometry: source centers near origin,
            // evaluation disk far away (ratio ≤ ~0.15 ⇒ truncation error
            // of the re-expansions ≲ 0.15^p)
            let z0 = C64::new(r.uniform_in(-0.2, 0.2), r.uniform_in(-0.2, 0.2));
            let z1 = C64::new(r.uniform_in(-0.3, 0.3), r.uniform_in(-0.3, 0.3));
            let zt = C64::new(4.0 + r.uniform(), 3.0 + r.uniform());
            (p, coeffs, z0, z1, zt)
        },
        |(p, coeffs, z0, z1, zt)| {
            // shifted expansions are p-term truncations: tolerance follows
            // the geometric bound with generous headroom
            let tol = (4.0 * 0.3f64.powi(*p as i32)).max(1e-10);
            let m0 = Coeffs(coeffs.clone());
            // (a) M2M then evaluate == evaluate original (far away)
            let mut m1 = Coeffs::zero(*p);
            if (*z0 - *z1).norm_sqr() > 0.0 {
                m2m_scaled(&m0, *z0, &mut m1, *z1);
                let direct_val = m2p(*z0, &m0, *zt);
                let shifted_val = m2p(*z1, &m1, *zt);
                prop::close(direct_val.re, shifted_val.re, tol)?;
                prop::close(direct_val.im, shifted_val.im, tol)?;
            }
            // (b) M2L then L2P == M2P at the local center
            let zl = *zt;
            let mut loc = Coeffs::zero(*p);
            m2l(&m0, *z0, &mut loc, zl);
            let at_center = l2p(zl, &loc, zl);
            let reference = m2p(*z0, &m0, zl);
            prop::close(at_center.re, reference.re, tol)?;
            prop::close(at_center.im, reference.im, tol)?;
            // (c) L2L preserves values inside the disk
            let zc = zl + C64::new(0.05, -0.03);
            let mut loc2 = Coeffs::zero(*p);
            l2l(&loc, zl, &mut loc2, zc);
            let a = l2p(zl, &loc, zc);
            let b = l2p(zc, &loc2, zc);
            prop::close(a.re, b.re, 1e-8)?;
            prop::close(a.im, b.im, 1e-8)?;
            Ok(())
        },
    );
}

#[test]
fn prop_p2m_m2p_roundtrip_random_sources() {
    prop::forall(
        Config { cases: 30, ..Default::default() },
        |r| {
            let n = 1 + r.below(30) as usize;
            let pts: Vec<C64> = (0..n)
                .map(|_| C64::new(r.uniform_in(-0.2, 0.2), r.uniform_in(-0.2, 0.2)))
                .collect();
            let gs: Vec<C64> = (0..n)
                .map(|_| C64::new(r.uniform_in(-1.0, 1.0), r.uniform_in(-1.0, 1.0)))
                .collect();
            let zt = C64::new(
                2.0 + 2.0 * r.uniform(),
                -2.0 - 2.0 * r.uniform(),
            );
            (pts, gs, zt)
        },
        |(pts, gs, zt)| {
            let mut m = Coeffs::zero(40);
            p2m(Kernel::Harmonic, C64::new(0.0, 0.0), pts, gs, &mut m);
            let approx = m2p(C64::new(0.0, 0.0), &m, *zt);
            let exact: C64 = pts
                .iter()
                .zip(gs)
                .map(|(&s, &g)| g * (s - *zt).recip())
                .sum();
            prop::close(approx.re, exact.re, 1e-9)?;
            prop::close(approx.im, exact.im, 1e-9)
        },
    );
}

#[test]
fn prop_direct_symmetric_equals_plain() {
    prop::forall(
        Config { cases: 20, ..Default::default() },
        |r| {
            let n = 2 + r.below(200) as usize;
            Distribution::Uniform.generate(n, r)
        },
        |(pts, gs)| {
            let a = direct::eval_plain(Kernel::Harmonic, pts, gs);
            let b = direct::eval_symmetric(Kernel::Harmonic, pts, gs);
            for (x, y) in a.iter().zip(&b) {
                prop::close(x.re, y.re, 1e-10)?;
                prop::close(x.im, y.im, 1e-10)?;
            }
            Ok(())
        },
    );
}
