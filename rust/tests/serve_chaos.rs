//! Chaos suite: the serve robustness layer under deterministic fault
//! injection. Needs `--features failpoints`; without it the whole file
//! compiles away (the default `cargo test` never arms anything).
//!
//! Every scenario holds [`failpoint::test_lock`] — the failpoint registry
//! is process-global — and ends disarmed. The invariant under test is
//! always the same: **no injected panic may cost a reply or the daemon**;
//! every accepted request is answered exactly once, and every `ok` answer
//! is bit-identical to an offline evaluation.

#![cfg(feature = "failpoints")]

use std::io::Cursor;

use fmm2d::fmm::{self, CpuEngine, FmmOptions};
use fmm2d::serve::loadgen::{self, LoadgenOptions};
use fmm2d::serve::{digest64, serve_lines, ServeOptions, ServeOutcome};
use fmm2d::util::failpoint;
use fmm2d::util::json::Json;
use fmm2d::workload::Distribution;

fn opts() -> ServeOptions {
    ServeOptions {
        fmm: FmmOptions {
            threads: Some(2),
            ..FmmOptions::default()
        },
        ..ServeOptions::default()
    }
}

fn run_session(input: &str, opts: ServeOptions) -> (Vec<Json>, ServeOutcome) {
    let mut out: Vec<u8> = Vec::new();
    let outcome = serve_lines(Cursor::new(input.to_string()), &mut out, opts).unwrap();
    let replies = String::from_utf8(out)
        .unwrap()
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap())
        .collect();
    (replies, outcome)
}

fn digest_requests(k: u64, n: usize) -> String {
    let mut s = String::new();
    for i in 0..k {
        s.push_str(&format!(
            "{{\"id\":{i},\"n\":{n},\"seed\":{},\"digest\":true}}\n",
            100 + i
        ));
    }
    s
}

/// Check every `ok` reply's digest against a quiet offline evaluation at
/// the advertised worker count (failpoints must already be disarmed).
fn assert_digests_match(replies: &[Json], n: usize) {
    for r in replies {
        if r.get("status").and_then(Json::as_str) != Some("ok") {
            continue;
        }
        let id = r.get("id").and_then(Json::as_usize).unwrap() as u64;
        let workers = r.get("workers").and_then(Json::as_usize).unwrap();
        let got = r.get("digest").and_then(Json::as_str).unwrap();
        let (pts, gs) = fmm2d::harness::workload_for(Distribution::Uniform, n, 100 + id);
        let offline = fmm::evaluate(
            &pts,
            &gs,
            &FmmOptions {
                threads: Some(workers),
                cpu_engine: CpuEngine::Barrier,
                ..FmmOptions::default()
            },
        )
        .unwrap();
        let want = format!("{:016x}", digest64(&offline.potentials));
        assert_eq!(got, want, "digest mismatch for id {id} ({workers} workers)");
    }
}

/// A panic in the serve dispatch path itself: the group is caught, the
/// pool rebuilt, the group split and re-run a rung down — and every
/// member still answers `ok` with a bit-correct digest.
#[test]
fn dispatch_panic_recovers_and_answers_everything() {
    let _g = failpoint::test_lock();
    failpoint::arm("dispatch=once:1").unwrap();
    let (replies, outcome) = run_session(&digest_requests(6, 500), opts());
    failpoint::disarm_all();

    assert_eq!(replies.len(), 6, "{replies:?}");
    for r in &replies {
        assert_eq!(r.get("status").and_then(Json::as_str), Some("ok"), "{r:?}");
    }
    let st = outcome.stats;
    assert_eq!(st.ok, 6);
    assert!(st.recoveries >= 1, "{st:?}");
    assert!(st.pool_rebuilds >= 1, "{st:?}");
    assert!(st.degraded >= 1, "{st:?}");
    assert_digests_match(&replies, 500);
}

/// A crash in the topology prologue (inside `fmm::evaluate`) is just as
/// recoverable: the unwind crosses the group `catch_unwind`, not the
/// process.
#[test]
fn topology_panic_is_isolated() {
    let _g = failpoint::test_lock();
    failpoint::arm("topology=once:1").unwrap();
    let (replies, outcome) = run_session(&digest_requests(4, 500), opts());
    failpoint::disarm_all();

    assert_eq!(replies.len(), 4, "{replies:?}");
    for r in &replies {
        assert_eq!(r.get("status").and_then(Json::as_str), Some("ok"), "{r:?}");
    }
    assert!(outcome.stats.recoveries >= 1, "{:?}", outcome.stats);
    assert_digests_match(&replies, 500);
}

/// A worker thread dying mid-task poisons the pooled evaluation; the
/// server tears the pool down, rebuilds it, and the retry (serial rung,
/// pool-free) completes every request.
#[test]
fn pool_worker_panic_rebuilds_the_pool() {
    let _g = failpoint::test_lock();
    failpoint::arm("pool-worker=once:3").unwrap();
    let (replies, outcome) = run_session(&digest_requests(4, 900), opts());
    failpoint::disarm_all();

    assert_eq!(replies.len(), 4, "{replies:?}");
    for r in &replies {
        assert_eq!(r.get("status").and_then(Json::as_str), Some("ok"), "{r:?}");
    }
    let st = outcome.stats;
    assert_eq!(st.ok, 4);
    assert!(st.recoveries >= 1, "{st:?}");
    assert!(st.pool_rebuilds >= 1, "{st:?}");
    assert_digests_match(&replies, 900);
}

/// Transient reply-write failures are retried inside the sink: the reply
/// stream stays complete and the retries are counted.
#[test]
fn write_failures_are_retried_not_lost() {
    let _g = failpoint::test_lock();
    failpoint::arm("write=every:2").unwrap();
    let (replies, outcome) = run_session(&digest_requests(6, 500), opts());
    failpoint::disarm_all();

    assert_eq!(replies.len(), 6, "every reply line present: {replies:?}");
    assert!(outcome.stats.write_retries >= 1, "{:?}", outcome.stats);
    assert_digests_match(&replies, 500);
}

/// The full chaos gate, in miniature: every failpoint armed at once under
/// sustained load with a saturating burst. The loadgen audit must come
/// back clean — zero lost replies, zero duplicates, zero digest
/// mismatches — and the server must have actually recovered (not merely
/// never been hit).
#[test]
fn loadgen_gate_holds_with_every_failpoint_armed() {
    let _g = failpoint::test_lock();
    failpoint::disarm_all();
    let opts = LoadgenOptions {
        rps: 150.0,
        duration_s: 0.4,
        mix: vec![(300, 3), (900, 1)],
        deadline_ms: 10_000,
        burst: 30,
        serve: ServeOptions {
            fmm: FmmOptions {
                threads: Some(2),
                ..FmmOptions::default()
            },
            max_queue: 64,
            ..ServeOptions::default()
        },
        faults: Some(
            "topology=every:11,dispatch=every:7,pool-worker=every:173,write=every:5".to_string(),
        ),
        ..LoadgenOptions::default()
    };
    let report = loadgen::run(&opts).unwrap();
    report.gate().unwrap_or_else(|e| panic!("chaos gate failed: {e:#}\n{}", report.render()));
    let st = report.server.expect("in-process run records server stats");
    assert!(st.recoveries >= 1, "no failpoint ever fired:\n{}", report.render());
    assert!(report.ok >= 1, "{}", report.render());
}
