//! Integration suite of the flight recorder (`obs`, DESIGN.md §12).
//!
//! The recorder's headline contract is *invisibility*: instrumentation
//! must never change what the engines compute. The first test pins that
//! at full strength — bitwise-identical potentials with tracing on and
//! off across the serial, pooled and task-graph engines. The rest pins
//! the observable surface: ring wraparound drops oldest-first with an
//! exact casualty count, the Chrome export round-trips through the strict
//! in-tree JSON parser with sane timestamps and feeds `trace-report`, the
//! span ledger agrees with the task-graph engine's own `OverlapStats`,
//! and the serve daemon answers the `{"op":"stats"}` wire request with a
//! registry snapshot that reconciles with the reply stream.
//!
//! The recorder is process-global, so every test serializes its
//! enable/disable window behind one mutex (same discipline as the unit
//! tests in `src/obs/mod.rs`).

use std::io::Cursor;
use std::sync::{Mutex, MutexGuard};

use fmm2d::complex::C64;
use fmm2d::config::FmmConfig;
use fmm2d::connectivity::Connectivity;
use fmm2d::fmm::parallel::evaluate_on_tree_pool;
use fmm2d::fmm::taskgraph::evaluate_on_tree_taskgraph_stats;
use fmm2d::fmm::{self, FmmOptions};
use fmm2d::obs;
use fmm2d::serve::{serve_lines, ServeOptions, ServeOutcome};
use fmm2d::tree::Pyramid;
use fmm2d::util::json::Json;
use fmm2d::util::pool::WorkerPool;
use fmm2d::util::rng::Pcg64;
use fmm2d::workload;

fn lock() -> MutexGuard<'static, ()> {
    static T: Mutex<()> = Mutex::new(());
    T.lock().unwrap_or_else(|p| p.into_inner())
}

struct Case {
    pyr: Pyramid,
    con: Connectivity,
}

fn case() -> Case {
    let mut r = Pcg64::seed_from_u64(41);
    let (pts, gs) = workload::uniform_square(2_000, &mut r);
    let pyr = Pyramid::build(&pts, &gs, 3).expect("3 levels fit 2000 points");
    let con = Connectivity::build(&pyr, 0.5);
    Case { pyr, con }
}

fn opts(threads: usize) -> FmmOptions {
    FmmOptions {
        cfg: FmmConfig {
            p: 8,
            levels_override: Some(3),
            ..FmmConfig::default()
        },
        threads: Some(threads),
        ..FmmOptions::default()
    }
}

fn assert_bitwise(a: &[C64], b: &[C64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.re.to_bits(), y.re.to_bits(), "{what}: re diverged at {i}");
        assert_eq!(x.im.to_bits(), y.im.to_bits(), "{what}: im diverged at {i}");
    }
}

/// Tracing must be *invisible*: the recorder only observes timestamps,
/// so every engine's potentials are bitwise-identical with the recorder
/// armed and disarmed.
#[test]
fn tracing_does_not_change_any_engine_output() {
    let _g = lock();
    let c = case();
    let pool = WorkerPool::new(2, false);
    let o = opts(2);

    obs::disable();
    let serial_off = fmm::evaluate_on_tree_serial(&c.pyr, &c.con, &o).0;
    let pooled_off = evaluate_on_tree_pool(&c.pyr, &c.con, &o, &pool).0;
    let tg_off = evaluate_on_tree_taskgraph_stats(&c.pyr, &c.con, &o, &pool, None).0;

    obs::enable(&obs::ObsOptions::default());
    let serial_on = fmm::evaluate_on_tree_serial(&c.pyr, &c.con, &o).0;
    let pooled_on = evaluate_on_tree_pool(&c.pyr, &c.con, &o, &pool).0;
    let tg_on = evaluate_on_tree_taskgraph_stats(&c.pyr, &c.con, &o, &pool, None).0;
    obs::disable();
    let tr = obs::drain();

    assert_bitwise(&serial_off, &serial_on, "serial");
    assert_bitwise(&pooled_off, &pooled_on, "pooled");
    assert_bitwise(&tg_off, &tg_on, "taskgraph");

    // and the armed window actually recorded the engines running
    assert!(
        tr.spans.iter().any(|s| s.cat == "phase" && s.name == "P2P"),
        "barrier engines record phase spans"
    );
    assert!(
        tr.spans.iter().any(|s| s.cat == "task"),
        "task-graph engine records task spans"
    );
    assert!(
        tr.spans.iter().any(|s| s.cat == "worker"),
        "worker pool records occupancy spans"
    );
}

/// A full ring overwrites oldest-first and counts every casualty.
#[test]
fn ring_wraparound_drops_oldest_and_counts() {
    let _g = lock();
    obs::enable(&obs::ObsOptions { capacity: 8 });
    for i in 0..20 {
        obs::event("wraptest", "seq", &[("i", i as f64)]);
    }
    obs::disable();
    let tr = obs::drain();
    let seqs: Vec<f64> = tr
        .spans
        .iter()
        .filter(|s| s.cat == "wraptest")
        .map(|s| s.args[0].1)
        .collect();
    let want: Vec<f64> = (12..20).map(|i| i as f64).collect();
    assert_eq!(seqs, want, "newest 8 survive, in chronological order");
    assert!(tr.dropped >= 12, "dropped {} < 12", tr.dropped);
}

/// A traced task-graph run exports as strict Chrome trace-event JSON —
/// parseable by the in-tree parser, timestamps non-negative and sorted —
/// and `trace-report` renders per-phase, occupancy and critical-path
/// sections from the file.
#[test]
fn chrome_export_roundtrips_and_feeds_trace_report() {
    let _g = lock();
    let c = case();
    let pool = WorkerPool::new(2, false);

    obs::enable(&obs::ObsOptions::default());
    let _ = evaluate_on_tree_taskgraph_stats(&c.pyr, &c.con, &opts(2), &pool, None);
    obs::disable();

    let path = std::env::temp_dir().join(format!("fmm2d-obs-test-{}.json", std::process::id()));
    let trace = obs::write_chrome_file(&path).expect("trace written");
    assert!(!trace.spans.is_empty(), "traced run produced spans");

    // round-trip through the strict parser with sane timestamps
    let text = std::fs::read_to_string(&path).unwrap();
    let back = Json::parse(&text).expect("strict JSON");
    let events = back.get("traceEvents").and_then(Json::as_arr).unwrap();
    let mut last_ts = -1.0;
    let mut complete = 0usize;
    for e in events {
        if e.get("ph").and_then(Json::as_str) == Some("X") {
            let ts = e.get("ts").and_then(Json::as_f64).unwrap();
            let dur = e.get("dur").and_then(Json::as_f64).unwrap();
            assert!(ts >= 0.0 && dur >= 0.0, "non-negative timestamps");
            assert!(ts >= last_ts, "X events sorted by ts");
            last_ts = ts;
            complete += 1;
        }
    }
    assert_eq!(complete, trace.spans.len(), "one X event per span");

    // the report renders the sections the issue promises
    let report = fmm2d::obs::report::render_file(&path).expect("report renders");
    assert!(report.contains("task-graph tasks"), "{report}");
    assert!(report.contains("worker occupancy"), "{report}");
    assert!(report.contains("critical path"), "{report}");
    assert!(report.contains("mean busy workers"), "{report}");
    let _ = std::fs::remove_file(&path);
}

/// The span ledger and the task-graph engine's own `OverlapStats` measure
/// the same busy time: Σ task-span durations ≈ `busy_s` (they bracket the
/// same intervals, so they agree within recording overhead).
#[test]
fn task_spans_agree_with_overlap_stats() {
    let _g = lock();
    let c = case();
    let pool = WorkerPool::new(2, false);

    obs::enable(&obs::ObsOptions::default());
    let (_, _, _, stats) = evaluate_on_tree_taskgraph_stats(&c.pyr, &c.con, &opts(2), &pool, None);
    obs::disable();
    let tr = obs::drain();

    let busy = obs::busy_seconds(&tr.spans, "task");
    assert!(stats.busy_s > 0.0 && busy > 0.0, "both ledgers saw work");
    let tol = (0.10 * stats.busy_s).max(0.010);
    assert!(
        (busy - stats.busy_s).abs() <= tol,
        "span busy {busy:.6}s vs OverlapStats busy {:.6}s (tol {tol:.6}s)",
        stats.busy_s
    );
}

/// Run one full serve session over an in-memory transport.
fn run_session(input: &str) -> (Vec<Json>, ServeOutcome) {
    let opts = ServeOptions {
        fmm: FmmOptions {
            threads: Some(2),
            ..FmmOptions::default()
        },
        ..ServeOptions::default()
    };
    let mut out: Vec<u8> = Vec::new();
    let outcome = serve_lines(Cursor::new(input.to_string()), &mut out, opts).unwrap();
    let replies = String::from_utf8(out)
        .unwrap()
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).unwrap())
        .collect();
    (replies, outcome)
}

/// The daemon answers `{"op":"stats"}` inline with a registry snapshot
/// whose admission counters reconcile exactly with the reply stream, and
/// rejects the op when it smuggles extra fields.
#[test]
fn serve_answers_the_stats_op_and_counters_reconcile() {
    let _g = lock();
    let input = concat!(
        "{\"id\":1,\"n\":300,\"seed\":5}\n",
        "{\"id\":2,\"n\":400,\"seed\":6}\n",
        "{\"op\":\"stats\"}\n",
        "{\"op\":\"stats\",\"id\":9}\n", // op takes no other fields
    );
    let (replies, outcome) = run_session(input);
    assert_eq!(replies.len(), 4, "{replies:?}");
    assert_eq!(outcome.stats.ok, 2);

    let stats = replies
        .iter()
        .find(|r| r.get("status").and_then(Json::as_str) == Some("stats"))
        .expect("stats reply present");
    let counter = |name: &str| -> f64 {
        stats
            .get("stats")
            .and_then(|s| s.get("counters"))
            .and_then(|c| c.get(&format!("serve.{name}")))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    // the reader admits eval lines in order before answering the op, so
    // admission counters are exact at snapshot time; completions may
    // still be in flight, so `ok` is bounded, not pinned
    assert_eq!(counter("accepted") + counter("shed"), 2.0);
    assert_eq!(counter("shed"), 0.0);
    assert!(counter("ok") <= 2.0);
    assert!(
        stats
            .get("stats")
            .and_then(|s| s.get("histograms"))
            .is_some(),
        "snapshot carries histogram section: {stats:?}"
    );

    let err = replies
        .iter()
        .find(|r| r.get("status").and_then(Json::as_str) == Some("error"))
        .expect("malformed op gets an error reply");
    assert_eq!(
        err.get("id").and_then(Json::as_f64),
        Some(9.0),
        "id salvaged from the bad op line: {err:?}"
    );
    assert_eq!(outcome.stats.rejected, 1, "bad op rejected at decode time");
    assert_eq!(outcome.stats.errors, 0);
}
