//! Parity of the multithreaded execution engine against the serial
//! reference driver: potentials to ≤ 1e-12 relative error, and *identical*
//! `WorkCounts` (the architecture-independent work description that the
//! GPU cost model consumes), across distributions × kernels × thread
//! counts.

use fmm2d::config::FmmConfig;
use fmm2d::connectivity::Connectivity;
use fmm2d::expansion::Kernel;
use fmm2d::fmm::{
    evaluate_on_tree_serial, parallel::evaluate_on_tree_parallel, FmmOptions, Phase, WorkCounts,
};
use fmm2d::tree::Pyramid;
use fmm2d::util::rng::Pcg64;
use fmm2d::workload::Distribution;

fn assert_counts_identical(a: &WorkCounts, b: &WorkCounts, what: &str) {
    assert_eq!(a.n, b.n, "{what}: n");
    assert_eq!(a.levels, b.levels, "{what}: levels");
    assert_eq!(a.p, b.p, "{what}: p");
    assert_eq!(a.leaf_sizes, b.leaf_sizes, "{what}: leaf_sizes");
    assert_eq!(a.m2l_per_level, b.m2l_per_level, "{what}: m2l_per_level");
    assert_eq!(a.m2m_per_level, b.m2m_per_level, "{what}: m2m_per_level");
    assert_eq!(a.l2l_per_level, b.l2l_per_level, "{what}: l2l_per_level");
    assert_eq!(a.p2p_pairs, b.p2p_pairs, "{what}: p2p_pairs");
    assert_eq!(
        a.p2p_src_per_box, b.p2p_src_per_box,
        "{what}: p2p_src_per_box"
    );
    assert_eq!(a.p2l_pairs, b.p2l_pairs, "{what}: p2l_pairs");
    assert_eq!(a.m2p_pairs, b.m2p_pairs, "{what}: m2p_pairs");
    assert_eq!(a.p2m_particles, b.p2m_particles, "{what}: p2m_particles");
    assert_eq!(a.connect_checks, b.connect_checks, "{what}: connect_checks");
}

#[test]
fn parallel_engine_matches_serial_across_the_grid() {
    let dists = [
        Distribution::Uniform,
        Distribution::Normal { sigma: 0.1 },
        Distribution::Layer { sigma: 0.05 },
    ];
    for (di, dist) in dists.iter().enumerate() {
        for kernel in [Kernel::Harmonic, Kernel::Log] {
            let mut r = Pcg64::seed_from_u64(100 + di as u64);
            let (pts, mut gs) = dist.generate(2500, &mut r);
            if kernel == Kernel::Log {
                // log potential: real strengths (see fmm tests)
                for g in gs.iter_mut() {
                    g.im = 0.0;
                }
            }
            let pyr = Pyramid::build(&pts, &gs, 3).unwrap();
            let con = Connectivity::build(&pyr, 0.5);
            let opts = FmmOptions {
                cfg: FmmConfig {
                    p: 14,
                    levels_override: Some(3),
                    ..FmmConfig::default()
                },
                kernel,
                // the symmetric fast path only applies to Harmonic; the
                // engine falls back to the directed formulation for Log
                symmetric_p2p: true,
                threads: Some(1),
                ..FmmOptions::default()
            };
            let what = format!("{} × {:?}", dist.name(), kernel);
            let (serial, st, sc) = evaluate_on_tree_serial(&pyr, &con, &opts);
            assert!(st.total() > 0.0, "{what}: serial times empty");
            for nt in [1usize, 2, 4] {
                let (par, pt, pc) = evaluate_on_tree_parallel(&pyr, &con, &opts, nt);
                assert_eq!(par.len(), serial.len());
                for (i, (a, b)) in serial.iter().zip(&par).enumerate() {
                    assert!(
                        (*a - *b).abs() <= 1e-12 * a.abs().max(1.0),
                        "{what} t={nt}: potential {i} diverged: {a:?} vs {b:?}"
                    );
                }
                assert_counts_identical(&sc, &pc, &format!("{what} t={nt}"));
                // PhaseTimes: same instrumentation shape — all computational
                // phases recorded, Sort/Connect slots left for the caller
                assert!(pt.total() > 0.0, "{what} t={nt}: no time recorded");
                assert!(pt.get(Phase::P2P) > 0.0, "{what} t={nt}: P2P not timed");
                assert!(pt.get(Phase::M2L) > 0.0, "{what} t={nt}: M2L not timed");
                assert_eq!(pt.get(Phase::Sort), 0.0, "{what} t={nt}: Sort slot");
                assert_eq!(pt.get(Phase::Connect), 0.0, "{what} t={nt}: Connect slot");
            }
        }
    }
}

#[test]
fn dispatch_selects_engine_by_thread_count() {
    // evaluate_on_tree with threads=Some(1) must be the serial driver
    // bit-for-bit; with threads=Some(4) it must agree to parity tolerance.
    let mut r = Pcg64::seed_from_u64(9);
    let (pts, gs) = Distribution::Uniform.generate(2000, &mut r);
    let pyr = Pyramid::build(&pts, &gs, 2).unwrap();
    let con = Connectivity::build(&pyr, 0.5);
    let base = FmmOptions {
        cfg: FmmConfig {
            p: 17,
            levels_override: Some(2),
            ..FmmConfig::default()
        },
        ..Default::default()
    };
    let one = FmmOptions {
        threads: Some(1),
        ..base.clone()
    };
    let four = FmmOptions {
        threads: Some(4),
        ..base
    };
    let (serial, _, _) = evaluate_on_tree_serial(&pyr, &con, &one);
    let (via_dispatch, _, _) = fmm2d::fmm::evaluate_on_tree(&pyr, &con, &one);
    for (a, b) in serial.iter().zip(&via_dispatch) {
        assert_eq!(a.re, b.re);
        assert_eq!(a.im, b.im);
    }
    let (par, _, _) = fmm2d::fmm::evaluate_on_tree(&pyr, &con, &four);
    for (a, b) in serial.iter().zip(&par) {
        assert!((*a - *b).abs() <= 1e-12 * a.abs().max(1.0));
    }
}

#[test]
fn full_evaluate_parity_in_original_order() {
    // end-to-end `evaluate` (sort + connect + compute + unpermute): the
    // user-facing results agree between engines in the caller's order.
    let mut r = Pcg64::seed_from_u64(77);
    let (pts, gs) = Distribution::Normal { sigma: 0.08 }.generate(3000, &mut r);
    let mk = |threads| FmmOptions {
        cfg: FmmConfig {
            p: 17,
            levels_override: Some(3),
            ..FmmConfig::default()
        },
        threads,
        ..Default::default()
    };
    let serial = fmm2d::fmm::evaluate(&pts, &gs, &mk(Some(1))).unwrap();
    let par = fmm2d::fmm::evaluate(&pts, &gs, &mk(Some(3))).unwrap();
    for (a, b) in serial.potentials.iter().zip(&par.potentials) {
        assert!((*a - *b).abs() <= 1e-12 * a.abs().max(1.0));
    }
    assert_eq!(serial.counts.p2p_pairs, par.counts.p2p_pairs);
    assert!(par.times.get(Phase::Sort) > 0.0);
}
