//! The headline acceptance contract of the persistent worker pool: once a
//! pool exists, a full `fmm::evaluate` — Sort, Connect and all six
//! computational phases, through the barrier engine *and* the task-graph
//! pipelined engine — performs **zero thread spawns**. Every spawn
//! site in the crate reports to `util::pool::note_spawn`, so the global
//! counter is a complete census.
//!
//! This test lives alone in its own integration-test binary (its own
//! process): spawn accounting is process-global, and tests from other
//! binaries run as separate processes, so nothing else can move the
//! counter between the snapshot and the assertion.

use std::sync::Arc;

use fmm2d::config::FmmConfig;
use fmm2d::fmm::{self, FmmOptions};
use fmm2d::util::pool::{self, WorkerPool};
use fmm2d::util::rng::Pcg64;
use fmm2d::workload::Distribution;

#[test]
fn full_evaluate_spawns_no_threads_after_pool_construction() {
    let pool = Arc::new(WorkerPool::new(3, false));
    let opts = FmmOptions {
        cfg: FmmConfig {
            p: 12,
            ..FmmConfig::default()
        },
        threads: Some(3),
        pool: Some(Arc::clone(&pool)),
        ..FmmOptions::default()
    };

    let mut r = Pcg64::seed_from_u64(5);
    let (pts, gs) = Distribution::Normal { sigma: 0.1 }.generate(4000, &mut r);

    // Warm-up: first evaluation (one-time lazy setup may not spawn either,
    // but the contract below is about steady state).
    let warm = fmm::evaluate(&pts, &gs, &opts).unwrap();

    let before = pool::spawn_count();
    let mut last = None;
    for seed in 0..3u64 {
        let mut r = Pcg64::seed_from_u64(50 + seed);
        let (pts, gs) = Distribution::Uniform.generate(2000 + 700 * seed as usize, &mut r);
        last = Some(fmm::evaluate(&pts, &gs, &opts).unwrap());
    }
    assert_eq!(
        pool::spawn_count(),
        before,
        "a full evaluate must spawn zero threads once the pool exists"
    );

    // sanity: the spawn-free evaluations really computed something
    let out = last.unwrap();
    assert_eq!(out.potentials.len(), 2000 + 700 * 2);
    assert!(out.counts.p2p_pairs > 0);
    assert!(out.times.total() > 0.0);
    assert_eq!(warm.potentials.len(), 4000);

    // the same holds for the directed (GPU-layout) near field
    let dir_opts = FmmOptions {
        symmetric_p2p: false,
        ..opts.clone()
    };
    let before = pool::spawn_count();
    let dir = fmm::evaluate(&pts, &gs, &dir_opts).unwrap();
    assert_eq!(pool::spawn_count(), before, "directed P2P path spawned");
    assert_eq!(dir.potentials.len(), pts.len());

    // the task-graph pipelined engine rides the same pool: the
    // dependency-gated ready queue dispatches onto existing workers, so
    // repeated evaluations spawn nothing either (symmetric and directed)
    for symmetric in [true, false] {
        let tg_opts = FmmOptions {
            cpu_engine: fmm::CpuEngine::TaskGraph,
            symmetric_p2p: symmetric,
            ..opts.clone()
        };
        let warm_tg = fmm::evaluate(&pts, &gs, &tg_opts).unwrap();
        assert_eq!(warm_tg.potentials.len(), pts.len());
        let before = pool::spawn_count();
        for _ in 0..3 {
            let tg = fmm::evaluate(&pts, &gs, &tg_opts).unwrap();
            assert_eq!(tg.potentials.len(), pts.len());
        }
        assert_eq!(
            pool::spawn_count(),
            before,
            "task-graph engine (symmetric={symmetric}) spawned"
        );
    }

    // accumulator-lease bound across engines: after the barrier and
    // task-graph engines have churned the lease, a fresh take is still
    // exactly one full lease per worker — nothing leaked, nothing grew
    let lease = pool.take_accums();
    assert_eq!(lease.len(), pool.n_workers(), "lease must stay complete");
    pool.return_accums(lease);
}
