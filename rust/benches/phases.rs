//! Micro-benchmarks of the FMM phases and their substrates (self-built
//! harness — criterion is unavailable offline).
//!
//! Run: `cargo bench --offline` or `cargo bench --offline -- <filter>`.

use fmm2d::bench::{bench, black_box, BenchConfig};
use fmm2d::complex::C64;
use fmm2d::config::FmmConfig;
use fmm2d::connectivity::Connectivity;
use fmm2d::expansion::shifts::{
    l2l_with, m2l_unscaled, m2l_with, m2m_scaled_with, ShiftScratch,
};
use fmm2d::expansion::{p2m, Coeffs, Kernel};
use fmm2d::fmm::{evaluate_on_tree, FmmOptions};
use fmm2d::tree::{PartitionEngine, Pyramid};
use fmm2d::util::rng::Pcg64;
use fmm2d::workload;

fn rand_coeffs(r: &mut Pcg64, p: usize) -> Vec<C64> {
    let mut v: Vec<C64> = (0..=p)
        .map(|_| C64::new(r.uniform_in(-1.0, 1.0), r.uniform_in(-1.0, 1.0)))
        .collect();
    v[0] = C64::new(0.0, 0.0);
    v
}

fn main() {
    // first non-flag argument is a name filter (cargo bench passes
    // `--bench`, which must be ignored)
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_default();
    let cfg = BenchConfig::default();
    let mut results = Vec::new();
    let mut run = |name: &str, f: &mut dyn FnMut()| {
        if !filter.is_empty() && !name.contains(&filter) {
            return;
        }
        let r = bench(name, &cfg, f);
        println!("{}", r.report());
        results.push(r);
    };

    let mut rng = Pcg64::seed_from_u64(1);

    // ---- shift operators at the paper's p = 17 and at the p = 42 cliff
    for p in [17usize, 42] {
        let a = rand_coeffs(&mut rng, p);
        let z_i = C64::new(0.1, 0.2);
        let z_o = C64::new(1.2, -0.5);
        let mut out = vec![C64::new(0.0, 0.0); p + 1];
        let mut scratch = ShiftScratch::new();
        run(&format!("m2l_recurrence_p{p}"), &mut || {
            out.fill(C64::new(0.0, 0.0));
            m2l_with(&a, z_i, &mut out, z_o, &mut scratch);
            black_box(&out);
        });
        let mut acc = Coeffs::zero(p);
        run(&format!("m2l_unscaled_p{p}"), &mut || {
            acc.clear();
            m2l_unscaled(&Coeffs(a.clone()), z_i, &mut acc, z_o);
            black_box(&acc);
        });
        let op = fmm2d::expansion::matrices::M2lOperator::new(p);
        let mut mscratch = fmm2d::expansion::matrices::M2lScratch::default();
        run(&format!("m2l_matrix_op_p{p}"), &mut || {
            out.fill(C64::new(0.0, 0.0));
            op.apply(&a, z_i, &mut out, z_o, &mut mscratch);
            black_box(&out);
        });
        run(&format!("m2m_scaled_p{p}"), &mut || {
            out.fill(C64::new(0.0, 0.0));
            m2m_scaled_with(&a, z_i, &mut out, z_o, &mut scratch);
            black_box(&out);
        });
        run(&format!("l2l_p{p}"), &mut || {
            out.fill(C64::new(0.0, 0.0));
            l2l_with(&a, z_i, &mut out, z_o, &mut scratch);
            black_box(&out);
        });
    }

    // ---- P2M over a 45-particle box
    {
        let (pts, gs) = workload::uniform_square(45, &mut rng);
        let z0 = C64::new(0.5, 0.5);
        let mut acc = Coeffs::zero(17);
        run("p2m_45_particles_p17", &mut || {
            acc.clear();
            p2m(Kernel::Harmonic, z0, &pts, &gs, &mut acc);
            black_box(&acc);
        });
    }

    // ---- topological phase at N = 100k: serial engines, the GPU
    // functional model, and the parallel topology engine per thread count
    {
        let (pts, gs) = workload::uniform_square(100_000, &mut rng);
        run("tree_build_cpu_100k_l5", &mut || {
            black_box(Pyramid::build(&pts, &gs, 5).unwrap());
        });
        run("tree_build_gpumodel_100k_l5", &mut || {
            black_box(
                Pyramid::build_with(&pts, &gs, 5, PartitionEngine::GpuModel).unwrap(),
            );
        });
        let pyr = Pyramid::build(&pts, &gs, 5).unwrap();
        run("connectivity_100k_l5", &mut || {
            black_box(Connectivity::build(&pyr, 0.5));
        });
        let max_t = fmm2d::util::threadpool::available_threads();
        let mut thread_counts = vec![2usize];
        while *thread_counts.last().unwrap() * 2 <= max_t {
            thread_counts.push(thread_counts.last().unwrap() * 2);
        }
        for &t in &thread_counts {
            run(&format!("tree_build_parallel_100k_l5_t{t}"), &mut || {
                black_box(
                    Pyramid::build_threaded(&pts, &gs, 5, PartitionEngine::Cpu, t).unwrap(),
                );
            });
            run(&format!("connectivity_parallel_100k_l5_t{t}"), &mut || {
                black_box(Connectivity::build_threaded(&pyr, 0.5, t));
            });
            run(&format!("topology_build_100k_l5_t{t}"), &mut || {
                black_box(
                    fmm2d::topology::build(
                        &pts,
                        &gs,
                        5,
                        &fmm2d::topology::TopologyOptions::parallel(0.5, t),
                    )
                    .unwrap(),
                );
            });
        }
    }

    // ---- whole computational phase (fixed tree): symmetric vs directed,
    // serial engine vs the multithreaded engine at every power-of-two
    // thread count up to the machine's parallelism
    {
        let (pts, gs) = workload::uniform_square(50_000, &mut rng);
        let pyr = Pyramid::build(&pts, &gs, 5).unwrap();
        let con = Connectivity::build(&pyr, 0.5);
        let max_t = fmm2d::util::threadpool::available_threads();
        let mut thread_counts = vec![1usize];
        while *thread_counts.last().unwrap() * 2 <= max_t {
            thread_counts.push(thread_counts.last().unwrap() * 2);
        }
        for (name, sym) in [("symmetric", true), ("directed", false)] {
            for &t in &thread_counts {
                let opts = FmmOptions {
                    cfg: FmmConfig {
                        p: 17,
                        levels_override: Some(5),
                        ..FmmConfig::default()
                    },
                    kernel: Kernel::Harmonic,
                    symmetric_p2p: sym,
                    threads: Some(t),
                    topo_threads: None,
                    ..FmmOptions::default()
                };
                if t == 1 {
                    run(&format!("fmm_compute_50k_{name}_serial_t1"), &mut || {
                        black_box(evaluate_on_tree(&pyr, &con, &opts));
                    });
                    continue;
                }
                // the persistent-pool engine (the production dispatch) vs
                // the scoped spawn-per-phase reference, same worker count
                let pool = fmm2d::util::pool::WorkerPool::new(t, false);
                run(&format!("fmm_compute_50k_{name}_pool_t{t}"), &mut || {
                    black_box(fmm2d::fmm::parallel::evaluate_on_tree_pool(
                        &pyr, &con, &opts, &pool,
                    ));
                });
                run(&format!("fmm_compute_50k_{name}_scoped_t{t}"), &mut || {
                    black_box(fmm2d::fmm::parallel::evaluate_on_tree_parallel(
                        &pyr, &con, &opts, t,
                    ));
                });
            }
        }
    }

    println!("\n{} benchmarks run", results.len());
}
