//! Macro-benchmark: regenerate every paper table/figure at the scaled
//! default size (DESIGN.md §3). This is `fmm2d all` packaged as
//! `cargo bench`, so `make bench` reproduces the whole evaluation section
//! in one command; per-figure wall-clock is reported.
//!
//! Includes the XLA-path benchmark (runtime executables vs serial CPU on
//! identical trees) when artifacts are present.

use std::time::Instant;

use fmm2d::harness::{self, HarnessOpts};

fn timed<F: FnOnce()>(name: &str, f: F) {
    let t = Instant::now();
    f();
    eprintln!("[{name}: {:.1} s]", t.elapsed().as_secs_f64());
}

#[cfg(not(feature = "pjrt"))]
fn xla_bench() {
    eprintln!("[xla_bench skipped: built without the `pjrt` feature]");
}

#[cfg(feature = "pjrt")]
fn xla_bench() {
    use fmm2d::config::FmmConfig;
    use fmm2d::connectivity::Connectivity;
    use fmm2d::expansion::Kernel;
    use fmm2d::fmm::{evaluate_on_tree, FmmOptions};
    use fmm2d::runtime::Runtime;
    use fmm2d::tree::Pyramid;
    use fmm2d::workload::Distribution;

    let Ok(mut rt) = Runtime::new(None) else {
        eprintln!("[xla_bench skipped: no PJRT]");
        return;
    };
    if rt.available().is_empty() {
        eprintln!("[xla_bench skipped: run `make artifacts`]");
        return;
    }
    println!("# XLA-path benchmark: AOT executable vs serial CPU (same tree)");
    println!(
        "{:<18} {:>8} {:>12} {:>12} {:>12} {:>10}",
        "artifact", "N", "exec[ms]", "serial[ms]", "upload[ms]", "agree"
    );
    for (levels, n) in [(2usize, 450usize), (3, 3_000), (4, 12_000)] {
        let (pts, gs) = harness::workload_for(Distribution::Uniform, n, 7);
        let pyr = Pyramid::build(&pts, &gs, levels).expect("bench sizes are valid");
        let con = Connectivity::build(&pyr, 0.5);
        let Ok(exe) = rt.fmm_artifact_for_tree(&pyr, &con) else { continue };
        let name = exe.meta.name.clone();
        // warm-up then measure median of 3
        let _ = exe.run_fmm(&pyr, &con);
        let mut execs = Vec::new();
        let mut uploads = Vec::new();
        let mut pot = Vec::new();
        for _ in 0..3 {
            let (p, stats) = exe.run_fmm(&pyr, &con).expect("artifact run");
            execs.push(stats.execute_s);
            uploads.push(stats.upload_s);
            pot = p;
        }
        execs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let opts = FmmOptions {
            cfg: FmmConfig {
                p: exe.meta.p,
                levels_override: Some(levels),
                ..FmmConfig::default()
            },
            kernel: Kernel::Harmonic,
            symmetric_p2p: true,
            threads: Some(1),
            topo_threads: None,
            ..FmmOptions::default()
        };
        let t = Instant::now();
        let (phi_leaf, _, _) = evaluate_on_tree(&pyr, &con, &opts);
        let serial_s = t.elapsed().as_secs_f64();
        let serial = pyr.unpermute(&phi_leaf);
        let agree = pot
            .iter()
            .zip(&serial)
            .map(|(a, b)| (*a - *b).abs() / b.abs().max(1e-12))
            .fold(0.0f64, f64::max);
        println!(
            "{name:<18} {n:>8} {:>12.1} {:>12.1} {:>12.2} {agree:>10.1e}",
            execs[1] * 1e3,
            serial_s * 1e3,
            uploads[1] * 1e3
        );
    }
}

fn main() {
    let o = HarnessOpts::default();
    timed("table5-1", || {
        let (text, rec) = harness::table5_1(&o);
        println!("{text}");
        rec.save("table5_1");
    });
    timed("fig5-1", || {
        let t = harness::fig5_1(&o);
        println!("{}", t.render());
        t.save("fig5_1");
    });
    timed("fig5-2", || {
        let t = harness::fig5_2(&o);
        println!("{}", t.render());
        t.save("fig5_2");
    });
    timed("fig5-3", || {
        let t = harness::fig5_3(&o);
        println!("{}", t.render());
        t.save("fig5_3");
    });
    timed("fig5-4", || {
        let (t, (a, b)) = harness::fig5_4(&o);
        println!("{}", t.render());
        println!("linear fit: opt_Nd_gpu ≈ {a:.1} + {b:.2}·p");
        t.save("fig5_4");
    });
    timed("fig5-5", || {
        let (t, be) = harness::fig5_5(&o);
        println!("{}", t.render());
        println!("GPU FMM/direct break-even ≈ N = {be:.0} (paper ≈ 3500)");
        t.save("fig5_5");
    });
    timed("fig5-6", || {
        let t = harness::fig5_6(&o);
        println!("{}", t.render());
        t.save("fig5_6");
    });
    timed("fig5-7", || {
        let t = harness::fig5_7(&o);
        println!("{}", t.render());
        t.save("fig5_7");
    });
    timed("fig5-8", || {
        let t = harness::fig5_8(&o);
        println!("{}", t.render());
        t.save("fig5_8");
    });
    timed("fig5-9", || {
        let t = harness::fig5_9(&o);
        println!("{}", t.render());
        t.save("fig5_9");
    });
    timed("validate", || {
        let t = harness::validate(&o);
        println!("{}", t.render());
        t.save("validate");
    });
    timed("xla_bench", xla_bench);
    println!("{}", harness::calibrate(&o));
}
