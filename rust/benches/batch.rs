//! Batched vs sequential execution of many small FMM problems
//! (self-built harness — criterion is unavailable offline).
//!
//! The acceptance claim of the batch subsystem: on the parallel CPU
//! engine, dispatching K small problems as a batch (one pooled worker
//! scope per group) is at least as fast as evaluating them one after
//! another (per-problem, per-phase thread spawn).
//!
//! Run: `cargo bench --bench batch --offline`.

use fmm2d::batch::{self, BatchEngine, BatchOptions, BatchProblem};
use fmm2d::bench::{bench, black_box, BenchConfig};
use fmm2d::config::FmmConfig;
use fmm2d::expansion::Kernel;
use fmm2d::fmm::{self, FmmOptions};
use fmm2d::util::rng::Pcg64;
use fmm2d::workload;

fn main() {
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_default();
    let cfg = BenchConfig::macro_bench();
    let mut results = Vec::new();
    let mut run = |name: &str, f: &mut dyn FnMut()| {
        if !filter.is_empty() && !name.contains(&filter) {
            return;
        }
        let r = bench(name, &cfg, f);
        println!("{}", r.report());
        results.push(r);
    };

    let mut rng = Pcg64::seed_from_u64(1);
    let k = 32usize;
    let n = 2000usize;
    let problems: Vec<BatchProblem> = (0..k)
        .map(|_| {
            let (points, gammas) = workload::uniform_square(n, &mut rng);
            BatchProblem { points, gammas }
        })
        .collect();
    let fmm_opts = FmmOptions {
        cfg: FmmConfig::default(),
        kernel: Kernel::Harmonic,
        symmetric_p2p: true,
        threads: None,
        topo_threads: None,
        ..FmmOptions::default()
    };

    // sequential baseline: per-problem evaluations through each engine
    run(&format!("sequential_serial_{k}x{n}"), &mut || {
        for pr in &problems {
            black_box(
                fmm::evaluate(
                    &pr.points,
                    &pr.gammas,
                    &FmmOptions {
                        threads: Some(1),
                        ..fmm_opts.clone()
                    },
                )
                .expect("bench problems are valid"),
            );
        }
    });
    run(&format!("sequential_parallel_{k}x{n}"), &mut || {
        for pr in &problems {
            black_box(
                fmm::evaluate(&pr.points, &pr.gammas, &fmm_opts)
                    .expect("bench problems are valid"),
            );
        }
    });

    // batched dispatches
    for (name, engine, overlap) in [
        ("batch_serial", BatchEngine::Serial, true),
        ("batch_parallel_seqprologue", BatchEngine::Parallel, false),
        ("batch_parallel", BatchEngine::Parallel, true),
    ] {
        let opts = BatchOptions {
            fmm: fmm_opts.clone(),
            engine,
            max_group: 0,
            overlap,
            ..BatchOptions::default()
        };
        run(&format!("{name}_{k}x{n}"), &mut || {
            black_box(batch::run(&problems, &opts).expect("CPU batch engines cannot fail"));
        });
    }

    // grouped-width sensitivity on the parallel engine
    for max_group in [4usize, 16] {
        let opts = BatchOptions {
            fmm: fmm_opts.clone(),
            engine: BatchEngine::Parallel,
            max_group,
            ..BatchOptions::default()
        };
        run(&format!("batch_parallel_{k}x{n}_g{max_group}"), &mut || {
            black_box(batch::run(&problems, &opts).expect("CPU batch engines cannot fail"));
        });
    }

    // dispatcher cross-check: the cost model's predicted batch time next
    // to the measured numbers above (fallback rates unless `fmm2d
    // calibrate` has written a profile)
    let d = fmm2d::dispatch::Dispatcher::load_or_default(None);
    let members: Vec<fmm2d::dispatch::Problem> = problems
        .iter()
        .map(|pr| fmm2d::dispatch::Problem::from_config(&fmm_opts.cfg, pr.points.len()))
        .collect();
    let dec = d.select_group(&members);
    println!(
        "dispatch cost model: would pick {} — predicted {:.6}s \
         (serial {:.6}s, pooled {:.6}s, gpu {:.6}s)",
        dec.choice, dec.predicted_s, dec.cost.serial_s, dec.cost.pooled_s, dec.cost.gpu_s
    );

    println!("\n{} benchmarks run", results.len());
}
