// Planted violations for the `no-adhoc-log` lint: raw stderr prints in a
// production module. Two before #[cfg(test)], one inside it (the in-test
// one must NOT be flagged). (Fixture — never compiled.)

pub fn load_profile(path: &str) -> Option<Profile> {
    let Ok(text) = std::fs::read_to_string(path) else {
        eprintln!("warning: could not read {path}");
        return None;
    };
    match Profile::parse(&text) {
        Ok(p) => Some(p),
        Err(e) => {
            eprintln!("warning: malformed profile {path}: {e}");
            None
        }
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn debug_prints_are_fine_in_tests() {
        eprintln!("tests may print freely");
    }
}
