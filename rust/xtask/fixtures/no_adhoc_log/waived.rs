// Waiver demonstration: deliberate raw stderr prints carrying the
// documented waiver syntax, both preceding-comment and same-line forms.
// (Fixture — never compiled.)

pub fn report_fatal(msg: &str) {
    // xtask: allow(no-adhoc-log) — fatal path runs before the logger exists
    eprintln!("fatal: {msg}");
}

pub fn banner() {
    eprintln!("fmm2d starting"); // xtask: allow(no-adhoc-log) — fixture same-line form
}
