// Clean twin of no_adhoc_log/bad.rs: the same diagnostics expressed via
// the leveled structured logger. A string or comment mentioning eprintln!
// must not trip the lint either. (Fixture — never compiled.)

pub fn load_profile(path: &str) -> Option<Profile> {
    let Ok(text) = std::fs::read_to_string(path) else {
        // the logger is the sanctioned stderr channel, not eprintln!
        crate::obs::log::warn("profile", "could not read file", &[("path", path.to_string())]);
        return None;
    };
    match Profile::parse(&text) {
        Ok(p) => Some(p),
        Err(e) => {
            let msg = "do not reach for eprintln! here";
            crate::obs::log::warn("profile", msg, &[("error", e.to_string())]);
            None
        }
    }
}
