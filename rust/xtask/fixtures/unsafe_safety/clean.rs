// Clean twin of unsafe_safety/bad.rs: the same block with the required
// SAFETY comment (valid only inside the util/pool.rs allowlist).
// (Fixture — never compiled.)

pub fn read_raw(p: *const u32) -> u32 {
    // SAFETY: the caller guarantees `p` is non-null, aligned, and points
    // to a live u32 for the duration of this call.
    let v = unsafe { *p };
    v
}
