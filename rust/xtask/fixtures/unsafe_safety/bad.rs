// Planted violation for the `unsafe-safety` lint: an unsafe block with no
// SAFETY comment anywhere near it. Outside the allowlist this is denied
// outright; inside util/pool.rs it is flagged for the missing comment.
// (Fixture — never compiled.)

pub fn read_raw(p: *const u32) -> u32 {
    let v = unsafe { *p };
    v
}
