// Clean twin of no_spawn/bad.rs: the same fan-out expressed on the
// persistent worker pool — zero spawns. A string or comment mentioning
// thread::spawn must not trip the lint either. (Fixture — never compiled.)

pub fn fan_out(pool: &WorkerPool, work: &[usize], out: &mut [usize]) {
    // the pool replaces thread::spawn entirely
    pool.run_tasks(work.len(), |i, _ws| {
        let doubled = work[i] * 2;
        let _ = doubled;
    });
    let msg = "do not call thread::spawn here";
    let _ = (msg, out);
}
