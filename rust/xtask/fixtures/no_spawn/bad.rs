// Planted violations for the `no-spawn` lint: direct spawns outside the
// two pool modules. (Fixture — never compiled.)

pub fn fan_out(work: Vec<usize>) -> Vec<usize> {
    std::thread::scope(|s| {
        let handles: Vec<_> = work.iter().map(|&w| s.spawn(move || w * 2)).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

pub fn detach() {
    std::thread::spawn(|| {});
}

pub fn named() {
    let _ = std::thread::Builder::new().name("w".into()).spawn(|| {});
}
