// Waiver demonstration: a deliberate scoped spawn carrying the documented
// waiver syntax, both same-line and preceding-comment forms.
// (Fixture — never compiled.)

pub fn reference_engine(work: Vec<usize>) -> Vec<usize> {
    // xtask: allow(no-spawn) — reference engine, benchmarked against the pool
    std::thread::scope(|s| {
        let handles: Vec<_> = work.iter().map(|&w| s.spawn(move || w + 1)).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

pub fn detached_helper() {
    std::thread::spawn(|| {}); // xtask: allow(no-spawn) — fixture same-line form
}
