// Clean twin of float_reduction/bad.rs: the worker-order merge idiom —
// per-worker partials combined in a fixed, explicit order — plus integer
// reductions, which are exact and allowed. (Fixture — never compiled.)

pub fn total_energy(per_worker: &[Vec<f64>]) -> Vec<f64> {
    let n = per_worker.first().map_or(0, Vec::len);
    let mut acc = vec![0.0f64; n];
    // worker-order merge: workers are visited 0..w, so the float addition
    // order is identical for every thread count
    for partial in per_worker {
        for (a, x) in acc.iter_mut().zip(partial) {
            *a += x;
        }
    }
    acc
}

pub fn total_pairs(counts: &[u64]) -> u64 {
    counts.iter().sum::<u64>()
}

pub fn total_boxes(counts: &[usize]) -> usize {
    counts.iter().sum::<usize>()
}
