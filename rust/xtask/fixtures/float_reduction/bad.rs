// Planted violations for the `float-reduction` lint: iterator reductions
// over floats whose association order depends on the iterator, breaking
// bitwise reproducibility across worker counts. (Fixture — never compiled.)

pub fn total_energy(parts: &[f64]) -> f64 {
    parts.iter().sum::<f64>()
}

pub fn accumulate(parts: &[f64]) -> f64 {
    parts.iter().fold(0.0, |acc, x| acc + x)
}

pub fn pairwise_max(parts: &[f64]) -> Option<f64> {
    parts.iter().copied().reduce(f64::max)
}
