// Planted violations for the `no-panic` lint: exactly three panicking
// calls before the test module, plus panics *inside* #[cfg(test)] that
// must NOT be flagged. (Fixture — never compiled.)

pub fn lookup(xs: &[f64], i: usize) -> f64 {
    let first = xs.first().unwrap();
    let second = xs.get(1).expect("needs two entries");
    if i >= xs.len() {
        panic!("index {i} out of bounds");
    }
    first + second + xs[i]
}

#[cfg(test)]
mod tests {
    #[test]
    fn panics_in_tests_are_fine() {
        let xs = vec![1.0, 2.0];
        assert_eq!(xs.first().unwrap(), &1.0);
        let _ = xs.get(1).expect("present");
        if xs.len() > 9 {
            unreachable!("test-only");
        }
    }
}
