// Clean twin of no_panic/bad.rs: the same lookups with Result plumbing
// and non-panicking combinators; unwraps only inside #[cfg(test)].
// (Fixture — never compiled.)

pub fn lookup(xs: &[f64], i: usize) -> Result<f64, String> {
    let first = xs.first().ok_or_else(|| "empty input".to_string())?;
    let second = xs.get(1).copied().unwrap_or(0.0);
    let third = xs.get(i).ok_or_else(|| format!("index {i} out of bounds"))?;
    Ok(first + second + third)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwraps_in_tests_are_fine() {
        assert_eq!(super::lookup(&[1.0, 2.0], 0).unwrap(), 4.0);
        super::lookup(&[], 0).expect_err("empty must fail");
    }
}
