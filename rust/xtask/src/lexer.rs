//! A minimal line-oriented Rust lexer for the lint pass.
//!
//! The offline toolchain has no `syn`, so the lints run on a token-level
//! view instead of an AST: for every source line we produce the line's
//! *code* with comments and string/char literals blanked out (so substring
//! patterns cannot false-positive inside a string or a doc comment) and,
//! separately, the text of any *comment* on that line (so the lints can
//! recognise `// SAFETY:` annotations and `xtask: allow(...)` waivers).
//!
//! Handled: line comments, nested block comments, plain/byte strings with
//! escapes, raw strings `r#"…"#` (any hash depth, `b` prefix), char
//! literals, lifetimes. Multi-line strings and block comments carry their
//! state across lines.

/// One source line, split into its code and comment parts.
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// The line with comments and literal *contents* removed.
    pub code: String,
    /// Concatenated text of all comments on the line.
    pub comment: String,
}

#[derive(Clone, Copy, PartialEq)]
enum State {
    Code,
    LineComment,
    /// Block comment with nesting depth.
    Block(u32),
    /// Plain or byte string literal.
    Str,
    /// Raw string literal with its hash count.
    RawStr(u32),
}

/// Split `src` into lexed [`Line`]s.
pub fn lex(src: &str) -> Vec<Line> {
    let b: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut state = State::Code;
    let mut i = 0usize;
    // true when the previous code char could end an identifier (so a
    // following `r"` is not a raw-string prefix, e.g. in `attr "x"` split
    // weirdly — conservative but safe)
    let mut prev_ident = false;

    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            lines.push(std::mem::take(&mut cur));
            if state == State::LineComment {
                state = State::Code;
            }
            prev_ident = false;
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '/' && b.get(i + 1) == Some(&'/') {
                    state = State::LineComment;
                    i += 2;
                    continue;
                }
                if c == '/' && b.get(i + 1) == Some(&'*') {
                    state = State::Block(1);
                    i += 2;
                    continue;
                }
                if c == '"' {
                    state = State::Str;
                    cur.code.push(' ');
                    i += 1;
                    prev_ident = false;
                    continue;
                }
                // raw / byte string prefixes: r", r#…", b", br#…"
                if !prev_ident && (c == 'r' || c == 'b') {
                    let mut j = i + 1;
                    if c == 'b' && b.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let mut hashes = 0u32;
                    while b.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    let is_raw = c == 'r' || (c == 'b' && b.get(i + 1) == Some(&'r'));
                    if b.get(j) == Some(&'"') && (is_raw || hashes == 0) {
                        state = if is_raw { State::RawStr(hashes) } else { State::Str };
                        cur.code.push(' ');
                        i = j + 1;
                        continue;
                    }
                }
                // char literal vs lifetime
                if c == '\'' {
                    if b.get(i + 1) == Some(&'\\') {
                        // escaped char literal: skip to the closing quote
                        let mut j = i + 2;
                        if j < b.len() {
                            j += 1; // the escaped character itself
                        }
                        while j < b.len() && b[j] != '\'' && b[j] != '\n' {
                            j += 1;
                        }
                        cur.code.push(' ');
                        i = (j + 1).min(b.len());
                        prev_ident = false;
                        continue;
                    }
                    if b.get(i + 2) == Some(&'\'') && b.get(i + 1) != Some(&'\'') {
                        cur.code.push(' ');
                        i += 3;
                        prev_ident = false;
                        continue;
                    }
                    // lifetime or label: keep as code
                    cur.code.push(c);
                    prev_ident = false;
                    i += 1;
                    continue;
                }
                cur.code.push(c);
                prev_ident = c.is_alphanumeric() || c == '_';
                i += 1;
            }
            State::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            State::Block(depth) => {
                if c == '/' && b.get(i + 1) == Some(&'*') {
                    state = State::Block(depth + 1);
                    i += 2;
                } else if c == '*' && b.get(i + 1) == Some(&'/') {
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::Block(depth - 1)
                    };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // a `\<newline>` continuation still ends the physical
                    // line — keep the Line vector aligned with the file
                    if b.get(i + 1) == Some(&'\n') {
                        lines.push(std::mem::take(&mut cur));
                    }
                    i += 2; // skip the escaped char (incl. \" and \\)
                } else if c == '"' {
                    state = State::Code;
                    cur.code.push(' ');
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let mut ok = true;
                    for k in 0..hashes as usize {
                        if b.get(i + 1 + k) != Some(&'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        state = State::Code;
                        cur.code.push(' ');
                        i += 1 + hashes as usize;
                        continue;
                    }
                }
                i += 1;
            }
        }
    }
    lines.push(cur);
    lines
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        lex(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strings_and_comments_are_blanked() {
        let ls = lex("let x = \"unsafe // not code\"; // unsafe comment\n");
        assert!(!ls[0].code.contains("unsafe"));
        assert!(ls[0].comment.contains("unsafe comment"));
        assert!(ls[0].code.contains("let x ="));
    }

    #[test]
    fn nested_block_comments() {
        let ls = lex("a /* x /* y */ z */ b\nc\n");
        assert_eq!(ls[0].code.replace(' ', ""), "ab");
        assert!(ls[0].comment.contains('y'));
        assert_eq!(ls[1].code, "c");
    }

    #[test]
    fn multiline_string_spans_lines() {
        let ls = codes("let s = \"line1\nthread::spawn\n\"; end();\n");
        assert!(!ls.concat().contains("thread::spawn"));
        assert!(ls[2].contains("end()"));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let ls = codes("let s = r#\"a \" b panic!( \"# ; after();\n");
        assert!(!ls[0].contains("panic!"));
        assert!(ls[0].contains("after()"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let ls = codes("fn f<'a>(x: &'a str) { let c = '\\n'; let q = '\"'; g(); }\n");
        assert!(ls[0].contains("<'a>"));
        assert!(ls[0].contains("g()"));
        // the quote char literal must not open a string state
        assert!(ls[0].contains("let q ="));
    }

    #[test]
    fn string_line_continuation_keeps_line_count() {
        let src = "let s = \"part one \\\n    part two\";\nnext();\n";
        let ls = lex(src);
        // 3 physical lines + the trailing empty slot after the last \n
        assert_eq!(ls.len(), 4);
        assert_eq!(ls[2].code, "next();");
    }

    #[test]
    fn line_comment_ends_at_newline() {
        let ls = lex("// only comment\ncode();\n");
        assert!(ls[0].code.trim().is_empty());
        assert_eq!(ls[1].code, "code();");
    }
}
