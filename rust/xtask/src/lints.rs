//! The repo-specific lint catalog (see DESIGN.md §8).
//!
//! Six lints, each enforcing an invariant the codebase promises
//! informally and the test suite checks only by example:
//!
//! * `no-spawn` — no `thread::spawn` / `thread::scope` / `thread::Builder`
//!   outside `util/pool.rs` and `util/threadpool.rs` (the source-level twin
//!   of `tests/zero_spawn.rs`);
//! * `unsafe-safety` — every `unsafe` carries a nearby `// SAFETY:`
//!   comment, and `unsafe` outside `util/pool.rs` is denied outright;
//! * `no-panic` — no `unwrap`/`expect`/`panic!`-family calls in the
//!   engine/topology/dispatch hot paths outside `#[cfg(test)]` (keeps the
//!   Result plumbing honest);
//! * `float-reduction` — no iterator float reductions (`sum::<f64>`,
//!   `fold(0.0`, `.reduce(`) in the parallel-engine files, where bitwise
//!   reproducibility requires the explicit worker-order `merge` loops;
//! * `no-new-deps` — the `[dependencies]` sections of every manifest stay
//!   empty except the in-tree optional `xla` stub; `dev-`/`build-`
//!   dependencies are denied everywhere;
//! * `no-adhoc-log` — no raw `eprintln!` in `src/` outside `obs/` and
//!   `main.rs`, outside `#[cfg(test)]` (diagnostics go through the
//!   leveled `crate::obs::log` facility so `--log-level` governs them).
//!
//! Waiver syntax (same line or in the comment/attribute block immediately
//! above the flagged line):
//!
//! ```text
//! // xtask: allow(no-spawn) — reference engine, measured against the pool
//! std::thread::scope(|s| { ... })
//! ```
//!
//! Being token-level (no AST), the lints have known lexical limits: a
//! float reduction without a turbofish (`.sum()` on an f64 iterator) or a
//! renamed import (`use std::thread as t`) would slip through. The
//! fixture corpus under `fixtures/` pins the behaviour that *is* promised:
//! every lint flags its planted violation and passes the clean twin.

use std::path::Path;

use crate::lexer::{lex, Line};

/// One lint violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Lint name as used in `xtask: allow(...)`.
    pub lint: &'static str,
    /// Path relative to the repo root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
}

/// Files allowed to spawn threads (the two pool implementations).
const SPAWN_ALLOWLIST: [&str; 2] = ["rust/src/util/pool.rs", "rust/src/util/threadpool.rs"];
/// Files allowed to contain `unsafe` at all.
const UNSAFE_ALLOWLIST: [&str; 1] = ["rust/src/util/pool.rs"];
/// Hot-path directories where panicking calls are denied. `serve/` is held
/// to the same bar: a panic in the daemon is a dropped reply, so its only
/// permitted panics are the explicitly waivered fault-injection sites.
const NO_PANIC_DIRS: [&str; 4] = [
    "rust/src/fmm/",
    "rust/src/topology/",
    "rust/src/dispatch/",
    "rust/src/serve/",
];
/// Locations where a raw `eprintln!` is sanctioned: the logging facility
/// itself (its single sink) and `main.rs` (usage text, fatal-error exit,
/// and the post-run trace summary — all emitted before/after the logger's
/// jurisdiction). Everything else routes stderr through `crate::obs::log`.
const ADHOC_LOG_ALLOW_DIR: &str = "rust/src/obs/";
const ADHOC_LOG_ALLOW_FILE: &str = "rust/src/main.rs";
/// Parallel-engine files where iterator float reductions are denied.
const FLOAT_REDUCTION_FILES: [&str; 7] = [
    "rust/src/fmm/parallel.rs",
    "rust/src/fmm/taskgraph.rs",
    "rust/src/tiles/mod.rs",
    "rust/src/tree/mod.rs",
    "rust/src/connectivity/mod.rs",
    "rust/src/topology/mod.rs",
    "rust/src/batch/runner.rs",
];

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Does `code` contain `word` delimited by non-identifier characters?
fn has_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(p) = code[start..].find(word) {
        let a = start + p;
        let b = a + word.len();
        let before_ok = a == 0 || !is_ident(bytes[a - 1]);
        let after_ok = b >= code.len() || !is_ident(bytes[b]);
        if before_ok && after_ok {
            return true;
        }
        start = a + 1;
    }
    false
}

/// Is the finding at `idx` waived — `xtask: allow(<lint>)` on the same
/// line, or in the contiguous block of comments/attributes directly above?
fn waived(lines: &[Line], idx: usize, lint: &str) -> bool {
    let tag = format!("xtask: allow({lint})");
    for j in (0..=idx).rev() {
        let l = &lines[j];
        if l.comment.contains(&tag) {
            return true;
        }
        if j == idx {
            continue; // the flagged line itself may carry code
        }
        let t = l.code.trim();
        let comment_only = t.is_empty() && !l.comment.is_empty();
        let attribute = t.starts_with("#[") || t.starts_with("#!");
        if !(comment_only || attribute) {
            return false;
        }
    }
    false
}

/// Is there a `SAFETY:` comment on this line or within the `window` lines
/// above it?
fn has_safety_comment(lines: &[Line], idx: usize, window: usize) -> bool {
    let lo = idx.saturating_sub(window);
    lines[lo..=idx].iter().any(|l| l.comment.contains("SAFETY:"))
}

/// Index of the first line opening a `#[cfg(test)]` section, if any (test
/// modules sit at the end of every file in this tree).
fn test_section_start(lines: &[Line]) -> usize {
    lines
        .iter()
        .position(|l| l.code.contains("#[cfg(test)]"))
        .unwrap_or(lines.len())
}

/// Run the five source lints over one lexed `.rs` file.
pub fn lint_source(rel: &str, lines: &[Line]) -> Vec<Finding> {
    let mut out = Vec::new();
    let spawn_allowed = SPAWN_ALLOWLIST.iter().any(|f| rel == *f);
    let unsafe_allowed = UNSAFE_ALLOWLIST.iter().any(|f| rel == *f);
    let panic_scoped = NO_PANIC_DIRS.iter().any(|d| rel.starts_with(d));
    let float_scoped = FLOAT_REDUCTION_FILES.iter().any(|f| rel == *f);
    let log_allowed = rel.starts_with(ADHOC_LOG_ALLOW_DIR) || rel == ADHOC_LOG_ALLOW_FILE;
    let tests_from = test_section_start(lines);

    for (i, l) in lines.iter().enumerate() {
        let code = l.code.as_str();
        let lineno = i + 1;

        // no-spawn
        if !spawn_allowed {
            for pat in ["thread::spawn", "thread::scope", "thread::Builder"] {
                if code.contains(pat) && !waived(lines, i, "no-spawn") {
                    out.push(Finding {
                        lint: "no-spawn",
                        file: rel.to_string(),
                        line: lineno,
                        message: format!(
                            "`{pat}` outside util/pool.rs and util/threadpool.rs \
                             (production paths must run on the persistent pool)"
                        ),
                    });
                    break;
                }
            }
        }

        // unsafe-safety
        if has_word(code, "unsafe") {
            if !unsafe_allowed && !waived(lines, i, "unsafe-safety") {
                out.push(Finding {
                    lint: "unsafe-safety",
                    file: rel.to_string(),
                    line: lineno,
                    message: "new `unsafe` outside util/pool.rs is denied".to_string(),
                });
            } else if unsafe_allowed
                && !has_safety_comment(lines, i, 5)
                && !waived(lines, i, "unsafe-safety")
            {
                out.push(Finding {
                    lint: "unsafe-safety",
                    file: rel.to_string(),
                    line: lineno,
                    message: "`unsafe` without a `// SAFETY:` comment within 5 lines"
                        .to_string(),
                });
            }
        }

        // no-panic (hot paths, outside #[cfg(test)])
        if panic_scoped && i < tests_from {
            for pat in [
                ".unwrap()",
                ".expect(",
                "panic!(",
                "unreachable!(",
                "todo!(",
                "unimplemented!(",
            ] {
                if code.contains(pat) && !waived(lines, i, "no-panic") {
                    out.push(Finding {
                        lint: "no-panic",
                        file: rel.to_string(),
                        line: lineno,
                        message: format!(
                            "`{pat}` in a hot path — plumb a Result instead \
                             (or waive with an argument for infallibility)"
                        ),
                    });
                    break;
                }
            }
        }

        // no-adhoc-log (everywhere outside obs/ and main.rs, outside tests)
        if !log_allowed && i < tests_from && code.contains("eprintln!")
            && !waived(lines, i, "no-adhoc-log")
        {
            out.push(Finding {
                lint: "no-adhoc-log",
                file: rel.to_string(),
                line: lineno,
                message: "raw `eprintln!` outside obs/ and main.rs — route \
                          diagnostics through the leveled structured logger \
                          (`crate::obs::log::{error,warn,info,debug}`)"
                    .to_string(),
            });
        }

        // float-reduction (parallel-engine files)
        if float_scoped {
            for pat in [
                "sum::<f64>",
                "sum::<C64>",
                ".fold(0.0",
                ".fold(C64::new(",
                ".reduce(",
            ] {
                if code.contains(pat) && !waived(lines, i, "float-reduction") {
                    out.push(Finding {
                        lint: "float-reduction",
                        file: rel.to_string(),
                        line: lineno,
                        message: format!(
                            "`{pat}` in a parallel-engine file — floating-point \
                             reductions must use the explicit worker-order merge loops \
                             so results stay bitwise reproducible"
                        ),
                    });
                    break;
                }
            }
        }
    }
    out
}

/// Manifest keys allowed in dependency sections: (file, section, key).
const DEP_ALLOWLIST: [(&str, &str, &str); 1] = [("rust/Cargo.toml", "dependencies", "xla")];

/// Run the `no-new-deps` lint over one `Cargo.toml`.
pub fn lint_manifest(rel: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut section = String::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        let waived_here = raw.contains("xtask: allow(no-new-deps)");
        if line.starts_with('[') && line.ends_with(']') {
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let is_dep_section = section == "dependencies"
            || section == "dev-dependencies"
            || section == "build-dependencies"
            || section.ends_with(".dependencies")
            || section.ends_with(".dev-dependencies")
            || section.ends_with(".build-dependencies");
        if !is_dep_section || line.is_empty() {
            continue;
        }
        let key = line
            .split('=')
            .next()
            .unwrap_or("")
            .trim()
            .trim_matches('"')
            .split('.')
            .next()
            .unwrap_or("")
            .to_string();
        if key.is_empty() {
            continue;
        }
        let allowed = DEP_ALLOWLIST
            .iter()
            .any(|(f, s, k)| rel == *f && section == *s && key == *k);
        if !allowed && !waived_here {
            out.push(Finding {
                lint: "no-new-deps",
                file: rel.to_string(),
                line: i + 1,
                message: format!(
                    "dependency `{key}` in [{section}] — the tree builds with zero \
                     external crates; vendor in-tree or gate behind a feature stub"
                ),
            });
        }
    }
    out
}

/// Walk the repo and run every lint. `root` is the repository root (the
/// directory holding the workspace `Cargo.toml`).
pub fn run(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut findings = Vec::new();

    let src = root.join("rust/src");
    let mut rs_files = Vec::new();
    collect_rs(&src, &mut rs_files)?;
    rs_files.sort();
    for path in rs_files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = std::fs::read_to_string(&path)?;
        findings.extend(lint_source(&rel, &lex(&text)));
    }

    for rel in [
        "Cargo.toml",
        "rust/Cargo.toml",
        "rust/xla-stub/Cargo.toml",
        "rust/xtask/Cargo.toml",
    ] {
        let path = root.join(rel);
        if path.exists() {
            findings.extend(lint_manifest(rel, &std::fs::read_to_string(&path)?));
        }
    }
    Ok(findings)
}

/// Recursively collect `.rs` files (skipping nothing inside `rust/src` —
/// fixtures live outside it).
fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn lints_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.lint).collect()
    }

    // -- no-spawn ---------------------------------------------------------

    #[test]
    fn no_spawn_flags_bad_fixture() {
        let src = include_str!("../fixtures/no_spawn/bad.rs");
        let f = lint_source("rust/src/harness/fixture.rs", &lex(src));
        assert!(
            f.iter().filter(|f| f.lint == "no-spawn").count() >= 2,
            "{f:?}"
        );
    }

    #[test]
    fn no_spawn_passes_clean_fixture() {
        let src = include_str!("../fixtures/no_spawn/clean.rs");
        let f = lint_source("rust/src/harness/fixture.rs", &lex(src));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn no_spawn_honours_waivers() {
        let src = include_str!("../fixtures/no_spawn/waived.rs");
        let f = lint_source("rust/src/harness/fixture.rs", &lex(src));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn no_spawn_allowlists_the_pools() {
        let src = include_str!("../fixtures/no_spawn/bad.rs");
        let f = lint_source("rust/src/util/pool.rs", &lex(src));
        assert!(!lints_of(&f).contains(&"no-spawn"), "{f:?}");
    }

    // -- unsafe-safety ----------------------------------------------------

    #[test]
    fn unsafe_outside_allowlist_is_denied() {
        let src = include_str!("../fixtures/unsafe_safety/bad.rs");
        let f = lint_source("rust/src/fmm/fixture.rs", &lex(src));
        assert!(lints_of(&f).contains(&"unsafe-safety"), "{f:?}");
    }

    #[test]
    fn unsafe_in_pool_requires_safety_comment() {
        let bad = include_str!("../fixtures/unsafe_safety/bad.rs");
        let f = lint_source("rust/src/util/pool.rs", &lex(bad));
        assert!(
            f.iter()
                .any(|f| f.lint == "unsafe-safety" && f.message.contains("SAFETY")),
            "{f:?}"
        );
        let clean = include_str!("../fixtures/unsafe_safety/clean.rs");
        let f = lint_source("rust/src/util/pool.rs", &lex(clean));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn unsafe_in_a_string_is_not_flagged() {
        let f = lint_source(
            "rust/src/fmm/fixture.rs",
            &lex("let s = \"unsafe\"; // mentions unsafe\n"),
        );
        assert!(f.is_empty(), "{f:?}");
    }

    // -- no-panic ---------------------------------------------------------

    #[test]
    fn no_panic_flags_bad_fixture_outside_tests_only() {
        let src = include_str!("../fixtures/no_panic/bad.rs");
        let f = lint_source("rust/src/fmm/fixture.rs", &lex(src));
        let n = f.iter().filter(|f| f.lint == "no-panic").count();
        // three planted violations before #[cfg(test)], none after
        assert_eq!(n, 3, "{f:?}");
    }

    #[test]
    fn no_panic_passes_clean_fixture() {
        let src = include_str!("../fixtures/no_panic/clean.rs");
        let f = lint_source("rust/src/fmm/fixture.rs", &lex(src));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn no_panic_only_applies_to_hot_paths() {
        let src = include_str!("../fixtures/no_panic/bad.rs");
        let f = lint_source("rust/src/harness/fixture.rs", &lex(src));
        assert!(!lints_of(&f).contains(&"no-panic"), "{f:?}");
    }

    #[test]
    fn no_panic_applies_to_serve() {
        // the serve daemon is a no-panic zone like the engine hot paths:
        // an unwound reply is a lost reply
        let src = include_str!("../fixtures/no_panic/bad.rs");
        let f = lint_source("rust/src/serve/fixture.rs", &lex(src));
        assert_eq!(
            f.iter().filter(|f| f.lint == "no-panic").count(),
            3,
            "{f:?}"
        );
    }

    // -- float-reduction --------------------------------------------------

    #[test]
    fn float_reduction_flags_bad_fixture() {
        let src = include_str!("../fixtures/float_reduction/bad.rs");
        let f = lint_source("rust/src/fmm/parallel.rs", &lex(src));
        assert!(
            f.iter().filter(|f| f.lint == "float-reduction").count() >= 2,
            "{f:?}"
        );
    }

    #[test]
    fn float_reduction_passes_clean_fixture() {
        let src = include_str!("../fixtures/float_reduction/clean.rs");
        let f = lint_source("rust/src/fmm/parallel.rs", &lex(src));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn float_reduction_only_applies_to_engine_files() {
        let src = include_str!("../fixtures/float_reduction/bad.rs");
        let f = lint_source("rust/src/harness/fixture.rs", &lex(src));
        assert!(!lints_of(&f).contains(&"float-reduction"), "{f:?}");
    }

    // -- no-adhoc-log -----------------------------------------------------

    #[test]
    fn no_adhoc_log_flags_bad_fixture_outside_tests_only() {
        let src = include_str!("../fixtures/no_adhoc_log/bad.rs");
        let f = lint_source("rust/src/harness/fixture.rs", &lex(src));
        // two planted violations before #[cfg(test)], none after
        assert_eq!(
            f.iter().filter(|f| f.lint == "no-adhoc-log").count(),
            2,
            "{f:?}"
        );
    }

    #[test]
    fn no_adhoc_log_passes_clean_fixture() {
        let src = include_str!("../fixtures/no_adhoc_log/clean.rs");
        let f = lint_source("rust/src/harness/fixture.rs", &lex(src));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn no_adhoc_log_honours_waivers() {
        let src = include_str!("../fixtures/no_adhoc_log/waived.rs");
        let f = lint_source("rust/src/harness/fixture.rs", &lex(src));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn no_adhoc_log_allowlists_obs_and_main() {
        let src = include_str!("../fixtures/no_adhoc_log/bad.rs");
        for rel in ["rust/src/obs/log.rs", "rust/src/main.rs"] {
            let f = lint_source(rel, &lex(src));
            assert!(!lints_of(&f).contains(&"no-adhoc-log"), "{rel}: {f:?}");
        }
    }

    // -- no-new-deps ------------------------------------------------------

    #[test]
    fn no_new_deps_flags_bad_manifest() {
        let text = include_str!("../fixtures/no_new_deps/bad.toml");
        let f = lint_manifest("rust/Cargo.toml", text);
        assert!(
            f.iter().filter(|f| f.lint == "no-new-deps").count() >= 2,
            "{f:?}"
        );
    }

    #[test]
    fn no_new_deps_passes_clean_manifest() {
        let text = include_str!("../fixtures/no_new_deps/clean.toml");
        let f = lint_manifest("rust/Cargo.toml", text);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn no_new_deps_xla_only_allowed_in_fmm2d() {
        let text = include_str!("../fixtures/no_new_deps/clean.toml");
        let f = lint_manifest("rust/xtask/Cargo.toml", text);
        assert!(lints_of(&f).contains(&"no-new-deps"), "{f:?}");
    }

    // -- waiver mechanics -------------------------------------------------

    #[test]
    fn waiver_applies_through_attributes_but_not_past_code() {
        let src = "\
// xtask: allow(no-spawn) — fixture
#[inline]
std::thread::spawn(|| ());
";
        let f = lint_source("rust/src/harness/fixture.rs", &lex(src));
        assert!(f.is_empty(), "{f:?}");

        let src = "\
// xtask: allow(no-spawn) — fixture
let x = 1;
std::thread::spawn(|| ());
";
        let f = lint_source("rust/src/harness/fixture.rs", &lex(src));
        assert!(lints_of(&f).contains(&"no-spawn"), "{f:?}");
    }

    #[test]
    fn waiver_is_lint_specific() {
        let src = "\
// xtask: allow(no-panic) — wrong lint name
std::thread::spawn(|| ());
";
        let f = lint_source("rust/src/harness/fixture.rs", &lex(src));
        assert!(lints_of(&f).contains(&"no-spawn"), "{f:?}");
    }

    // -- the real tree ----------------------------------------------------

    #[test]
    fn the_shipped_tree_is_clean() {
        // xtask always compiles from its in-tree location, so the repo
        // root is two levels up from this crate's manifest.
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("../..")
            .canonicalize()
            .expect("repo root");
        let f = run(&root).expect("lint walk");
        assert!(f.is_empty(), "lint findings on the shipped tree: {f:#?}");
    }
}
