//! `cargo xtask` — repo-specific dev tooling.
//!
//! The only subcommand today is `lint`, the static-analysis pass described
//! in DESIGN.md §8 (invoke as `cargo xtask lint` via the alias in
//! `.cargo/config.toml`, or `cargo run -p xtask -- lint`):
//!
//! ```text
//! cargo xtask lint [--json] [--root PATH]
//! ```
//!
//! Exit code 0 when the tree is clean, 1 with a report (human-readable by
//! default, a machine-readable JSON document with `--json`) otherwise.

mod lexer;
mod lints;

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(&args[1..]),
        Some("--help" | "-h" | "help") | None => {
            print!("{USAGE}");
            ExitCode::SUCCESS
        }
        Some(other) => {
            eprintln!("unknown xtask subcommand '{other}'\n\n{USAGE}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "\
usage: cargo xtask <command>

commands:
  lint [--json] [--root PATH]   run the repo lint pass (DESIGN.md \u{a7}8)
                                  --json   machine-readable report on stdout
                                  --root   repo root (default: auto-detected)
";

fn lint(args: &[String]) -> ExitCode {
    let mut json = false;
    let mut root: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--json" => json = true,
            "--root" => match it.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("--root needs a value");
                    return ExitCode::FAILURE;
                }
            },
            other => {
                eprintln!("unknown lint option '{other}'\n\n{USAGE}");
                return ExitCode::FAILURE;
            }
        }
    }
    // xtask always compiles in-tree, so the repo root defaults to two
    // levels above this crate's manifest — stable no matter where the
    // `cargo xtask` invocation happens inside the workspace.
    let root = root.unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../.."));

    let findings = match lints::run(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("xtask lint: cannot walk {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };

    if json {
        println!("{}", report_json(&findings));
    } else if findings.is_empty() {
        println!("xtask lint: clean");
    } else {
        for f in &findings {
            println!("{}:{}: [{}] {}", f.file, f.line, f.lint, f.message);
        }
        println!(
            "xtask lint: {} finding(s) — waive with `// xtask: allow(<lint>) — reason`",
            findings.len()
        );
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

/// The machine-readable report: a single JSON object, schema version 1.
fn report_json(findings: &[lints::Finding]) -> String {
    let mut s = String::from("{\"version\":1,\"ok\":");
    s.push_str(if findings.is_empty() { "true" } else { "false" });
    s.push_str(",\"findings\":[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("{\"lint\":");
        json_str(&mut s, f.lint);
        s.push_str(",\"file\":");
        json_str(&mut s, &f.file);
        s.push_str(&format!(",\"line\":{}", f.line));
        s.push_str(",\"message\":");
        json_str(&mut s, &f.message);
        s.push('}');
    }
    s.push_str("]}");
    s
}

fn json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_report_is_parseable_shape() {
        let f = vec![lints::Finding {
            lint: "no-spawn",
            file: "rust/src/x.rs".into(),
            line: 3,
            message: "a \"quoted\" message".into(),
        }];
        let s = report_json(&f);
        assert!(s.starts_with("{\"version\":1,\"ok\":false"));
        assert!(s.contains("\\\"quoted\\\""));
        assert!(s.ends_with("]}"));
        assert_eq!(report_json(&[]), "{\"version\":1,\"ok\":true,\"findings\":[]}");
    }
}
