//! API-compatible stub of the `xla` (xla_extension 0.5.x) bindings.
//!
//! The real bindings link the native `libxla_extension`, which cannot be
//! downloaded or built in the offline CI container. This crate provides the
//! exact API surface that `fmm2d::runtime` consumes, so `--features pjrt`
//! type-checks and builds everywhere; every entry point that would touch
//! PJRT returns [`Error::Unavailable`] with an actionable message instead.
//!
//! Deployments that have the native library swap this stub for the real
//! bindings by editing the `xla` dependency in `rust/Cargo.toml` (Cargo
//! `[patch]` sections cannot override path dependencies):
//!
//! ```toml
//! [dependencies]
//! xla = { path = "/opt/xla-rs", optional = true }   # instead of "xla-stub"
//! ```

use std::fmt;

/// Stub error: the native runtime is not linked into this build.
#[derive(Debug)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: xla_extension is not linked into this build (the `pjrt` \
                 feature was compiled against the bundled API stub; point the \
                 `xla` dependency at a real xla-rs checkout to execute artifacts)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _priv: () }
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stub of `xla::Literal`.
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal { _priv: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _priv: () })
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_runtime_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("xla_extension"), "got: {msg}");
    }
}
